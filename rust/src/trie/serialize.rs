//! Trie persistence — the feature the paper's amortization argument
//! implies ("creating a ruleset is typically a one-time task"): build the
//! Trie of Rules once, save it, and serve queries from the saved structure
//! without re-mining.
//!
//! Versioned little-endian binary format. **v4** (current, DESIGN.md §17)
//! writes *succinct* columns laid out for zero-deserialization `mmap`
//! serving:
//!
//! ```text
//! magic "TOR\x01" | version u32 (= 4)
//! preamble, LEB128 varints: num_transactions, min_count, num_items,
//!   freqs…, vocab flag u8 (if 1: per item varint len + utf-8 bytes),
//!   num_rows, num_rules, section_count | crc32 u32 over every preceding
//!   byte | zero-pad to 64
//! TOC: section_count × 32-byte entries
//!   { id u8, codec u8, width u8, flags u8, crc32(payload) u32,
//!     count u64, offset u64 (absolute, 64-aligned), len u64 }
//!   | crc32 u32 over the entries | zero-pad to 64
//! sections, ascending id, each 64-aligned and zero-padded to 64:
//!   1 items (frequency ranks)      2 count deltas (parent − node)
//!   3 parents      4 depths        5 subtree_end    6 child_offsets
//!   7 child items (ranks)          8 child_targets
//!   9 header_offsets              10 header_nodes
//!   16+slot optional metric columns (raw f64 / quantized f32)
//! ```
//!
//! Structure payloads are bit-packed at the minimal width of the column's
//! maximum (codec 0, [`crate::util::bitpack`]) or raw `u64` when wider
//! than 56 bits (codec 1). Items are re-coded by frequency rank; counts
//! are stored as the delta against the parent's count (a child's support
//! never exceeds its parent's, so deltas are small and decode in preorder
//! where the parent always precedes the child). The 64-byte alignment and
//! per-section CRCs let [`open`] serve queries **directly from an `mmap`**
//! — validation is one CRC pass plus one structural sweep over the packed
//! data; nothing is decoded into heap columns. [`open_trusted`] goes
//! further for files this process wrote itself (the durability plane's
//! checkpoints): it verifies the preamble + TOC seals and every section
//! extent, then serves without touching the payload bytes at all — cold
//! open is O(header), not O(file), which is what makes restart instant.
//!
//! **v3** writes the frozen columnar layout directly — one
//! length-prefixed column per array, CRC32 trailer ([`save_v3_to`] keeps
//! this writer for interop). Metric columns are *derived* state (pure
//! functions of counts, parent counts and item frequencies) and are
//! recomputed on load rather than stored (v4 may optionally embed them
//! for zero-copy column scans). The derived structural columns (subtree
//! ranges, both CSRs) are stored *and* re-validated on load; any
//! disagreement rejects the file.
//!
//! **v2** (v3 body, no trailer) and the **v1** node-record format
//! (`num_nodes u32` + `(item u32, parent u32, count u64)` triples in
//! parent-before-child order) are still read; v1 files rebuild through
//! [`TrieBuilder`] and freeze, and can still be written via [`save_v1`]
//! for downgrade/interop.
//!
//! Durability (DESIGN.md §16): every path-level writer here goes through
//! write-temp + `sync_all` + atomic rename ([`fsio::atomic_write_with`]),
//! so a crash mid-save can never destroy the previous good file, and all
//! writers/loaders are additionally exposed as `*_with` variants over the
//! injectable [`Vfs`] so the chaos harness can exercise them against
//! simulated torn writes and I/O faults. Loaders report typed
//! [`LoadError`]s — [`LoadError::Corrupt`] (bad CRC, truncation, failed
//! re-derivation) is distinguished from [`LoadError::BadVersion`] — and
//! never panic on malformed input (fuzzed in
//! `rust/tests/serialization_golden.rs`).
//!
//! Because the frozen trie is preorder-renumbered with item-sorted
//! siblings and the header is a rank-indexed CSR (no hash-map iteration
//! anywhere), two builds from identical input serialize to identical
//! bytes — tested in `rust/tests/freeze.rs`.

use std::io::{BufReader, Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::transaction::TransactionDb;
use crate::data::vocab::{ItemId, Vocab};
use crate::mining::counts::ItemOrder;
use crate::rules::metrics::Metric;
use crate::trie::builder::TrieBuilder;
use crate::trie::node::{NodeIdx, ROOT, ROOT_ITEM};
use crate::trie::store::{
    MappedColumns, MappedSections, SectionView, CODEC_BITPACK, CODEC_F32Q, CODEC_F64, CODEC_U64,
};
use crate::trie::trie::TrieOfRules;
use crate::util::crc32::{Crc32, Crc32Writer};
use crate::util::fsio::{self, RealVfs, Vfs};
use crate::util::{bitpack, varint};

const MAGIC: [u8; 4] = *b"TOR\x01";
const VERSION_V1: u32 = 1;
const VERSION_V2: u32 = 2;
const VERSION_V3: u32 = 3;
const VERSION_V4: u32 = 4;

/// v4 layout alignment: every section starts on a 64-byte boundary (cache
/// line; a fortiori 8-byte aligned for zero-copy f64 column views).
const V4_ALIGN: usize = 64;

// v4 section ids (TOC `id` byte). 1–10 are the required structure
// sections; `16 + metric slot` are the optional metric columns.
const SEC_ITEMS_RANK: u8 = 1;
const SEC_COUNT_DELTA: u8 = 2;
const SEC_PARENTS: u8 = 3;
const SEC_DEPTHS: u8 = 4;
const SEC_SUBTREE_END: u8 = 5;
const SEC_CHILD_OFFSETS: u8 = 6;
const SEC_CHILD_ITEMS_RANK: u8 = 7;
const SEC_CHILD_TARGETS: u8 = 8;
const SEC_HEADER_OFFSETS: u8 = 9;
const SEC_HEADER_NODES: u8 = 10;
const SEC_METRIC_BASE: u8 = 16;

/// How [`encode_v4_opts`] persists the ten metric columns. They are
/// always derivable from the structure sections; embedding trades file
/// size for zero-copy (`Raw`) or approximate (`Quantized`) column scans.
/// The default writer ([`save`]/[`save_to`]) omits them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricMode {
    /// No metric sections (smallest file; columns derived on demand).
    Omit,
    /// Raw `f64` sections (codec 2) — served zero-copy from the map and
    /// verified bit-identical against the derivation on owned loads.
    Raw,
    /// Quantized `f32` sections (codec 3) — half the metric bytes; mapped
    /// serving ignores them in favor of exact derivation (they exist for
    /// the compression-ablation bench and external readers).
    Quantized,
}

/// Magic of the incremental delta sidecar (`<snapshot>.delta`).
const DELTA_MAGIC: [u8; 4] = *b"TORD";
const DELTA_VERSION_V1: u32 = 1;
const DELTA_VERSION: u32 = 2;

/// Magic of the checkpoint transaction-db dump (`ckpt-<id>.db`).
const DB_MAGIC: [u8; 4] = *b"TORB";
const DB_VERSION: u32 = 1;

// -- typed load errors ----------------------------------------------------

/// Why a persisted artifact failed to load. `Corrupt` (bad CRC, torn
/// frame, failed integrity re-derivation) is deliberately distinct from
/// `BadVersion` (well-formed file from a different format era): recovery
/// treats the former as a damaged artifact to skip and the latter as an
/// operator error.
#[derive(Debug)]
pub enum LoadError {
    /// The file is not one of ours at all.
    BadMagic,
    /// Recognized magic, unsupported format version.
    BadVersion(u32),
    /// Truncated, checksum-mismatched, or semantically inconsistent.
    Corrupt(String),
    /// The underlying I/O failed (open/read error, not EOF).
    Io(std::io::Error),
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::BadMagic => write!(f, "bad magic (not a Trie-of-Rules artifact)"),
            LoadError::BadVersion(v) => write!(f, "unsupported version {v}"),
            LoadError::Corrupt(msg) => write!(f, "corrupt artifact: {msg}"),
            LoadError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for LoadError {}

impl From<std::io::Error> for LoadError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            LoadError::Corrupt("truncated (unexpected end of file)".to_string())
        } else {
            LoadError::Io(e)
        }
    }
}

impl From<anyhow::Error> for LoadError {
    fn from(e: anyhow::Error) -> Self {
        LoadError::Corrupt(format!("{e:#}"))
    }
}

type LoadResult<T> = std::result::Result<T, LoadError>;

fn corrupt<T>(msg: impl Into<String>) -> LoadResult<T> {
    Err(LoadError::Corrupt(msg.into()))
}

// -- snapshot save --------------------------------------------------------

/// Save a trie (and optionally its vocabulary) to `path` in the current
/// (v4, succinct `mmap`-servable) format. Crash-safe: write-temp + fsync +
/// atomic rename.
pub fn save(trie: &TrieOfRules, vocab: Option<&Vocab>, path: &Path) -> Result<()> {
    save_with(&RealVfs, trie, vocab, path)
}

/// [`save`] over an injectable filesystem.
///
/// Copy-on-write fast path: a trie served straight from an `mmap`'d v4
/// image re-saves by copying the already-validated image bytes — no
/// re-encode through owned columns — whenever the image's vocab presence
/// matches the request (a mapped service's vocab *is* the image's).
pub fn save_with(
    vfs: &dyn Vfs,
    trie: &TrieOfRules,
    vocab: Option<&Vocab>,
    path: &Path,
) -> Result<()> {
    if let Some((image, has_vocab)) = trie.mapped_image() {
        if has_vocab == vocab.is_some() {
            return fsio::atomic_write_with(vfs, path, |w| w.write_all(image))
                .with_context(|| format!("save snapshot (cow) {}", path.display()));
        }
    }
    let bytes = encode_v4(trie, vocab)?;
    fsio::atomic_write_with(vfs, path, |w| w.write_all(&bytes))
        .with_context(|| format!("save snapshot {}", path.display()))
}

fn to_io(e: anyhow::Error) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::Other, format!("{e:#}"))
}

/// Save in the current v4 format to any writer (in-memory determinism
/// tests use a `Vec<u8>`).
pub fn save_to(trie: &TrieOfRules, vocab: Option<&Vocab>, w: &mut impl Write) -> Result<()> {
    let bytes = encode_v4(trie, vocab)?;
    w.write_all(&bytes)?;
    Ok(())
}

/// Save in the legacy v3 format (length-prefixed raw columns + CRC32
/// trailer) — interop/downgrade; new writes use the v4 [`save_to`].
pub fn save_v3_to(trie: &TrieOfRules, vocab: Option<&Vocab>, w: &mut impl Write) -> Result<()> {
    let mut cw = Crc32Writer::new(&mut *w);
    write_body(trie, vocab, VERSION_V3, &mut cw)?;
    let crc = cw.digest();
    w.write_all(&crc.to_le_bytes())?;
    Ok(())
}

/// Save in the legacy v2 format (no CRC trailer) — interop/downgrade and
/// the loader-hardening tests.
pub fn save_v2_to(trie: &TrieOfRules, vocab: Option<&Vocab>, w: &mut impl Write) -> Result<()> {
    write_body(trie, vocab, VERSION_V2, w)
}

fn write_body(
    trie: &TrieOfRules,
    vocab: Option<&Vocab>,
    version: u32,
    w: &mut impl Write,
) -> Result<()> {
    write_preamble(trie, vocab, version, w)?;
    write_col_u32(w, trie.items_column())?;
    write_col_u64(w, trie.counts_column())?;
    write_col_u32(w, trie.parents_column())?;
    write_col_u16(w, trie.depths_column())?;
    write_col_u32(w, trie.subtree_end_column())?;
    let (child_offsets, child_items, child_targets) = trie.child_csr();
    write_col_u32(w, child_offsets)?;
    write_col_u32(w, child_items)?;
    write_col_u32(w, child_targets)?;
    let (header_offsets, header_nodes) = trie.header_csr();
    write_col_u32(w, header_offsets)?;
    write_col_u32(w, header_nodes)?;
    Ok(())
}

/// Save in the legacy v1 node-record format (downgrade/interop path; new
/// writes should use [`save`]). Crash-safe like [`save`].
pub fn save_v1(trie: &TrieOfRules, vocab: Option<&Vocab>, path: &Path) -> Result<()> {
    fsio::atomic_write_with(&RealVfs, path, |mut w| {
        save_v1_to(trie, vocab, &mut w).map_err(to_io)
    })
    .with_context(|| format!("save v1 snapshot {}", path.display()))
}

/// v1 body writer (shared by [`save_v1`] and the golden-fixture tests).
pub fn save_v1_to(trie: &TrieOfRules, vocab: Option<&Vocab>, w: &mut impl Write) -> Result<()> {
    write_preamble(trie, vocab, VERSION_V1, w)?;
    let nodes: Vec<_> = trie.raw_nodes().collect();
    w.write_all(&(nodes.len() as u32).to_le_bytes())?;
    for (item, parent, count) in nodes {
        w.write_all(&item.to_le_bytes())?;
        w.write_all(&parent.to_le_bytes())?;
        w.write_all(&count.to_le_bytes())?;
    }
    Ok(())
}

fn write_preamble(
    trie: &TrieOfRules,
    vocab: Option<&Vocab>,
    version: u32,
    w: &mut impl Write,
) -> Result<()> {
    w.write_all(&MAGIC)?;
    w.write_all(&version.to_le_bytes())?;
    w.write_all(&(trie.num_transactions() as u64).to_le_bytes())?;
    w.write_all(&trie.order().min_count_used().to_le_bytes())?;
    let freqs = trie.order().frequencies();
    w.write_all(&(freqs.len() as u32).to_le_bytes())?;
    for &f0 in freqs {
        w.write_all(&f0.to_le_bytes())?;
    }
    match vocab {
        Some(v) => {
            anyhow::ensure!(
                v.len() == freqs.len(),
                "vocab size {} != item count {}",
                v.len(),
                freqs.len()
            );
            w.write_all(&[1u8])?;
            for name in v.names() {
                w.write_all(&(name.len() as u32).to_le_bytes())?;
                w.write_all(name.as_bytes())?;
            }
        }
        None => w.write_all(&[0u8])?,
    }
    Ok(())
}

// -- v4 writer ------------------------------------------------------------

/// Zero-pad `out` to the next [`V4_ALIGN`] boundary.
fn pad_align(out: &mut Vec<u8>) {
    let rem = out.len() % V4_ALIGN;
    if rem != 0 {
        out.resize(out.len() + (V4_ALIGN - rem), 0);
    }
}

/// `len` rounded up to the next [`V4_ALIGN`] boundary.
fn align_up(len: usize) -> usize {
    len.div_ceil(V4_ALIGN) * V4_ALIGN
}

struct V4SectionBuf {
    id: u8,
    codec: u8,
    width: u8,
    count: usize,
    payload: Vec<u8>,
}

/// Encode one unsigned column at its minimal bit-packed width, falling
/// back to raw `u64` when the maximum needs more than 56 bits.
fn packed_section(id: u8, vals: &[u64]) -> V4SectionBuf {
    let max = vals.iter().copied().max().unwrap_or(0);
    let width = bitpack::bits_for(max);
    if width <= bitpack::MAX_PACKED_WIDTH {
        V4SectionBuf {
            id,
            codec: CODEC_BITPACK,
            width,
            count: vals.len(),
            payload: bitpack::pack(vals, width),
        }
    } else {
        let mut payload = Vec::with_capacity(vals.len() * 8);
        for &v in vals {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        V4SectionBuf {
            id,
            codec: CODEC_U64,
            width: 64,
            count: vals.len(),
            payload,
        }
    }
}

/// Encode a trie as a v4 image with no metric sections (the default:
/// metrics are derived, smallest file).
pub fn encode_v4(trie: &TrieOfRules, vocab: Option<&Vocab>) -> Result<Vec<u8>> {
    encode_v4_opts(trie, vocab, MetricMode::Omit)
}

/// [`encode_v4`] with an explicit [`MetricMode`] (the compression-ablation
/// bench sweeps all three).
pub fn encode_v4_opts(
    trie: &TrieOfRules,
    vocab: Option<&Vocab>,
    metric_mode: MetricMode,
) -> Result<Vec<u8>> {
    let order = trie.order();
    let items = trie.items_column();
    let counts = trie.counts_column();
    let parents = trie.parents_column();
    let depths = trie.depths_column();
    let n = items.len();

    // Succinct re-codings: items by frequency rank, counts as the delta
    // against the parent (antimonotone ⇒ never underflows).
    let rank_of = |it: crate::data::vocab::ItemId| -> u64 {
        order.rank(it).expect("frozen trie items are frequent") as u64
    };
    let items_rank: Vec<u64> = items[1..].iter().map(|&it| rank_of(it)).collect();
    let count_delta: Vec<u64> = (1..n)
        .map(|i| counts[parents[i] as usize] - counts[i])
        .collect();
    let parents_v: Vec<u64> = parents[1..].iter().map(|&p| p as u64).collect();
    let depths_v: Vec<u64> = depths[1..].iter().map(|&d| d as u64).collect();
    let ste_v: Vec<u64> = trie.subtree_end_column().iter().map(|&v| v as u64).collect();
    let (co, ci, ct) = trie.child_csr();
    let co_v: Vec<u64> = co.iter().map(|&v| v as u64).collect();
    let ci_v: Vec<u64> = ci.iter().map(|&it| rank_of(it)).collect();
    let ct_v: Vec<u64> = ct.iter().map(|&v| v as u64).collect();
    let (ho, hn) = trie.header_csr();
    let ho_v: Vec<u64> = ho.iter().map(|&v| v as u64).collect();
    let hn_v: Vec<u64> = hn.iter().map(|&v| v as u64).collect();

    let mut sections = vec![
        packed_section(SEC_ITEMS_RANK, &items_rank),
        packed_section(SEC_COUNT_DELTA, &count_delta),
        packed_section(SEC_PARENTS, &parents_v),
        packed_section(SEC_DEPTHS, &depths_v),
        packed_section(SEC_SUBTREE_END, &ste_v),
        packed_section(SEC_CHILD_OFFSETS, &co_v),
        packed_section(SEC_CHILD_ITEMS_RANK, &ci_v),
        packed_section(SEC_CHILD_TARGETS, &ct_v),
        packed_section(SEC_HEADER_OFFSETS, &ho_v),
        packed_section(SEC_HEADER_NODES, &hn_v),
    ];
    match metric_mode {
        MetricMode::Omit => {}
        MetricMode::Raw => {
            for (slot, &m) in Metric::ALL.iter().enumerate() {
                let col = trie.metric_column(m);
                let mut payload = Vec::with_capacity(col.len() * 8);
                for &v in col {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
                sections.push(V4SectionBuf {
                    id: SEC_METRIC_BASE + slot as u8,
                    codec: CODEC_F64,
                    width: 64,
                    count: col.len(),
                    payload,
                });
            }
        }
        MetricMode::Quantized => {
            for (slot, &m) in Metric::ALL.iter().enumerate() {
                let col = trie.metric_column(m);
                let mut payload = Vec::with_capacity(col.len() * 4);
                for &v in col {
                    payload.extend_from_slice(&(v as f32).to_le_bytes());
                }
                sections.push(V4SectionBuf {
                    id: SEC_METRIC_BASE + slot as u8,
                    codec: CODEC_F32Q,
                    width: 32,
                    count: col.len(),
                    payload,
                });
            }
        }
    }

    // Head + varint preamble, sealed with its own CRC.
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION_V4.to_le_bytes());
    varint::encode_u64(&mut out, trie.num_transactions() as u64);
    varint::encode_u64(&mut out, order.min_count_used());
    let freqs = order.frequencies();
    varint::encode_u64(&mut out, freqs.len() as u64);
    for &f0 in freqs {
        varint::encode_u64(&mut out, f0);
    }
    match vocab {
        Some(v) => {
            anyhow::ensure!(
                v.len() == freqs.len(),
                "vocab size {} != item count {}",
                v.len(),
                freqs.len()
            );
            out.push(1);
            for name in v.names() {
                varint::encode_u64(&mut out, name.len() as u64);
                out.extend_from_slice(name.as_bytes());
            }
        }
        None => out.push(0),
    }
    varint::encode_u64(&mut out, n as u64);
    // Stored so a trusted open can skip the O(rows) structural sweep; the
    // validating paths cross-check it against the sweep's own count.
    varint::encode_u64(&mut out, trie.num_representable_rules() as u64);
    varint::encode_u64(&mut out, sections.len() as u64);
    let mut crc = Crc32::new();
    crc.update(&out);
    out.extend_from_slice(&crc.finish().to_le_bytes());
    pad_align(&mut out);

    // TOC: fixed 32-byte entries in ascending id order, absolute offsets.
    let toc_start = out.len();
    let toc_end = toc_start + align_up(sections.len() * 32 + 4);
    let mut offset = toc_end;
    for s in &sections {
        out.push(s.id);
        out.push(s.codec);
        out.push(s.width);
        out.push(0); // flags, reserved
        let mut pc = Crc32::new();
        pc.update(&s.payload);
        out.extend_from_slice(&pc.finish().to_le_bytes());
        out.extend_from_slice(&(s.count as u64).to_le_bytes());
        out.extend_from_slice(&(offset as u64).to_le_bytes());
        out.extend_from_slice(&(s.payload.len() as u64).to_le_bytes());
        offset += align_up(s.payload.len());
    }
    let mut tc = Crc32::new();
    tc.update(&out[toc_start..]);
    out.extend_from_slice(&tc.finish().to_le_bytes());
    pad_align(&mut out);
    debug_assert_eq!(out.len(), toc_end);

    for s in &sections {
        out.extend_from_slice(&s.payload);
        pad_align(&mut out);
    }
    debug_assert_eq!(out.len(), offset);
    Ok(out)
}

// -- snapshot load --------------------------------------------------------

/// Load a trie (and its vocabulary, when stored) from `path`. Reads the
/// current v3 (CRC-sealed) format plus legacy v2 and v1.
pub fn load(path: &Path) -> Result<(TrieOfRules, Option<Vocab>)> {
    let out = try_load(path).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(out)
}

/// [`load`] with a typed error.
pub fn try_load(path: &Path) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    try_load_with(&RealVfs, path)
}

/// [`try_load`] over an injectable filesystem.
pub fn try_load_with(vfs: &dyn Vfs, path: &Path) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    let f = vfs.open(path).map_err(LoadError::Io)?;
    let mut r = BufReader::new(f);
    try_load_from(&mut r)
}

/// Parse a snapshot from any reader (typed errors, never panics on
/// malformed input). For v3 the CRC trailer is verified *before* any
/// semantic validation, so a torn or bit-flipped file reports a checksum
/// failure rather than a misleading shape error.
pub fn try_load_from<R: Read>(r: &mut R) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    let mut head = [0u8; 8];
    r.read_exact(&mut head)?;
    if head[..4] != MAGIC {
        return Err(LoadError::BadMagic);
    }
    let version = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    match version {
        VERSION_V1 | VERSION_V2 => load_tail(r, version),
        VERSION_V3 => {
            let mut rest = Vec::new();
            r.read_to_end(&mut rest)?;
            let body = check_seal(&head, &rest)?;
            let mut br = body;
            let out = load_tail(&mut br, version)?;
            if !br.is_empty() {
                return corrupt(format!("{} trailing bytes after body", br.len()));
            }
            Ok(out)
        }
        VERSION_V4 => {
            // Reader-based v4 load: decode the sections into owned
            // columns (full `from_columns` validation). Zero-copy serving
            // is [`open`]'s job — it needs a mapping, not a reader.
            let mut full = head.to_vec();
            r.read_to_end(&mut full)?;
            load_v4_owned(&full)
        }
        other => Err(LoadError::BadVersion(other)),
    }
}

/// Verify a `crc32(head ++ body)` trailer; returns the body slice.
fn check_seal<'a>(head: &[u8], rest: &'a [u8]) -> LoadResult<&'a [u8]> {
    if rest.len() < 4 {
        return corrupt("truncated (missing checksum trailer)");
    }
    let (body, trailer) = rest.split_at(rest.len() - 4);
    let stored = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    let mut crc = crate::util::crc32::Crc32::new();
    crc.update(head);
    crc.update(body);
    let digest = crc.finish();
    if stored != digest {
        return corrupt(format!(
            "checksum mismatch: stored {stored:#010x}, computed {digest:#010x}"
        ));
    }
    Ok(body)
}

/// Everything after magic+version: preamble, vocab, then the
/// version-specific body.
fn load_tail<R: Read>(r: &mut R, version: u32) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    let num_transactions = read_u64(r)? as usize;
    let min_count = read_u64(r)?;
    let num_items = read_u32(r)? as usize;
    if num_items >= 1 << 28 {
        return corrupt(format!("implausible item count {num_items}"));
    }
    let mut freqs = Vec::with_capacity(num_items.min(1 << 16));
    for _ in 0..num_items {
        freqs.push(read_u64(r)?);
    }
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    if flag[0] > 1 {
        return corrupt(format!("bad vocab flag {}", flag[0]));
    }
    let vocab = if flag[0] == 1 {
        let mut v = Vocab::new();
        for i in 0..num_items {
            let len = read_u32(r)? as usize;
            if len >= 1 << 20 {
                return corrupt(format!("implausible name length {len}"));
            }
            let mut buf = vec![0u8; len];
            r.read_exact(&mut buf)?;
            let name = match String::from_utf8(buf) {
                Ok(s) => s,
                Err(_) => return corrupt(format!("item {i} name is not utf-8")),
            };
            v.intern(&name);
        }
        Some(v)
    } else {
        None
    };
    let order = ItemOrder::from_frequencies(freqs, min_count);
    let trie = match version {
        VERSION_V1 => load_v1_body(r, order, num_transactions)?,
        _ => load_v2_body(r, order, num_transactions)?,
    };
    Ok((trie, vocab))
}

fn load_v1_body<R: Read>(
    r: &mut R,
    order: ItemOrder,
    num_transactions: usize,
) -> LoadResult<TrieOfRules> {
    let num_nodes = read_u32(r)? as usize;
    if num_nodes >= 1 << 30 {
        return corrupt(format!("implausible node count {num_nodes}"));
    }
    let mut raw = Vec::with_capacity(num_nodes.min(1 << 16));
    for _ in 0..num_nodes {
        let item = read_u32(r)?;
        let parent = read_u32(r)?;
        let count = read_u64(r)?;
        raw.push((item, parent, count));
    }
    Ok(TrieBuilder::from_raw_nodes(order, num_transactions, &raw)?.freeze())
}

fn load_v2_body<R: Read>(
    r: &mut R,
    order: ItemOrder,
    num_transactions: usize,
) -> LoadResult<TrieOfRules> {
    let items = read_col_u32(r)?;
    let n = items.len();
    if n < 1 {
        return corrupt("empty items column");
    }
    let counts = read_col_u64(r)?;
    let parents = read_col_u32(r)?;
    let depths = read_col_u16(r)?;
    let subtree_end = read_col_u32(r)?;
    let child_offsets = read_col_u32(r)?;
    let child_items = read_col_u32(r)?;
    let child_targets = read_col_u32(r)?;
    let header_offsets = read_col_u32(r)?;
    let header_nodes = read_col_u32(r)?;
    // Shape checks before semantic validation.
    for (name, len, want) in [
        ("counts", counts.len(), n),
        ("parents", parents.len(), n),
        ("depths", depths.len(), n),
        ("subtree_end", subtree_end.len(), n),
        ("child_offsets", child_offsets.len(), n + 1),
        ("child_items", child_items.len(), n - 1),
        ("child_targets", child_targets.len(), n - 1),
        ("header_offsets", header_offsets.len(), order.num_frequent() + 1),
        ("header_nodes", header_nodes.len(), n - 1),
    ] {
        if len != want {
            return corrupt(format!("column {name}: {len} entries, expected {want}"));
        }
    }
    Ok(TrieOfRules::from_columns(
        order,
        num_transactions,
        items,
        counts,
        parents,
        depths,
        subtree_end,
        child_offsets,
        child_items,
        child_targets,
        header_offsets,
        header_nodes,
    )?)
}

// -- v4 parse / validate / open ------------------------------------------

/// A CRC-checked v4 image: preamble fields plus validated section views.
/// Shared by the owned decoder ([`try_load_from`]) and the zero-copy
/// openers ([`open_with_mode`]).
struct V4Parsed {
    order: ItemOrder,
    num_transactions: usize,
    num_rows: usize,
    /// The representable-rule count stored in the preamble. Trusted opens
    /// serve it directly; validating paths cross-check it against the
    /// structural sweep.
    representable: usize,
    has_vocab: bool,
    vocab: Option<Vocab>,
    sections: MappedSections,
}

fn v4_varint(bytes: &[u8], pos: &mut usize) -> LoadResult<u64> {
    varint::decode_u64(bytes, pos).map_err(|e| LoadError::Corrupt(format!("v4 preamble: {e}")))
}

/// Parse and checksum-verify a v4 image: preamble CRC, TOC CRC, per-entry
/// layout rules (known ids, expected counts, codec/width/length formulas,
/// 64-byte alignment, ascending non-overlapping extents), and — when
/// `verify_payloads` is set — per-section payload CRCs. Purely syntactic:
/// [`validate_v4_structure`] does the semantic sweep. With
/// `verify_payloads` off the parse touches only the header blocks (O(KB)
/// for any file size), which is the trusted-open fast path.
fn parse_v4(bytes: &[u8], verify_payloads: bool) -> LoadResult<V4Parsed> {
    if bytes.len() < 8 {
        return corrupt("truncated (missing header)");
    }
    if bytes[..4] != MAGIC {
        return Err(LoadError::BadMagic);
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != VERSION_V4 {
        return Err(LoadError::BadVersion(version));
    }
    if bytes.len() % V4_ALIGN != 0 {
        return corrupt(format!("file length {} not 64-byte aligned", bytes.len()));
    }

    // Preamble (varints), sealed by its own CRC.
    let mut pos = 8usize;
    let num_transactions = v4_varint(bytes, &mut pos)? as usize;
    let min_count = v4_varint(bytes, &mut pos)?;
    let num_items = v4_varint(bytes, &mut pos)? as usize;
    if num_items >= 1 << 28 {
        return corrupt(format!("implausible item count {num_items}"));
    }
    let mut freqs = Vec::with_capacity(num_items.min(1 << 16));
    for _ in 0..num_items {
        freqs.push(v4_varint(bytes, &mut pos)?);
    }
    let Some(&flag) = bytes.get(pos) else {
        return corrupt("truncated preamble (vocab flag)");
    };
    pos += 1;
    if flag > 1 {
        return corrupt(format!("bad vocab flag {flag}"));
    }
    let vocab = if flag == 1 {
        let mut v = Vocab::new();
        for i in 0..num_items {
            let len = v4_varint(bytes, &mut pos)? as usize;
            if len >= 1 << 20 {
                return corrupt(format!("implausible name length {len}"));
            }
            let Some(raw) = bytes.get(pos..pos + len) else {
                return corrupt("truncated preamble (vocab name)");
            };
            pos += len;
            match std::str::from_utf8(raw) {
                Ok(s) => {
                    v.intern(s);
                }
                Err(_) => return corrupt(format!("item {i} name is not utf-8")),
            }
        }
        Some(v)
    } else {
        None
    };
    let num_rows = v4_varint(bytes, &mut pos)? as usize;
    if num_rows < 1 || num_rows >= 1 << 30 {
        return corrupt(format!("implausible row count {num_rows}"));
    }
    let representable = v4_varint(bytes, &mut pos)?;
    // Each non-root row contributes depth - 1 rules, and depths fit u16.
    if representable > (num_rows as u64) * u16::MAX as u64 {
        return corrupt(format!("implausible rule count {representable}"));
    }
    let representable = representable as usize;
    let section_count = v4_varint(bytes, &mut pos)? as usize;
    if !(10..=30).contains(&section_count) {
        return corrupt(format!("implausible section count {section_count}"));
    }
    let Some(stored) = bytes.get(pos..pos + 4) else {
        return corrupt("truncated preamble (checksum)");
    };
    let stored_crc = u32::from_le_bytes(stored.try_into().unwrap());
    let mut crc = Crc32::new();
    crc.update(&bytes[..pos]);
    if stored_crc != crc.finish() {
        return corrupt(format!(
            "preamble checksum mismatch: stored {stored_crc:#010x}, computed {:#010x}",
            crc.finish()
        ));
    }
    pos = align_up(pos + 4);

    let order = ItemOrder::from_frequencies(freqs, min_count);
    let num_ranks = order.num_frequent();
    let n = num_rows;

    // TOC, sealed by its own CRC.
    let entries_len = section_count * 32;
    let Some(entry_bytes) = bytes.get(pos..pos + entries_len) else {
        return corrupt("truncated table of contents");
    };
    let Some(stored) = bytes.get(pos + entries_len..pos + entries_len + 4) else {
        return corrupt("truncated table of contents (checksum)");
    };
    let stored_crc = u32::from_le_bytes(stored.try_into().unwrap());
    let mut crc = Crc32::new();
    crc.update(entry_bytes);
    if stored_crc != crc.finish() {
        return corrupt("table-of-contents checksum mismatch");
    }
    let toc_end = align_up(pos + entries_len + 4);

    let expected_count = |id: u8| -> Option<usize> {
        match id {
            SEC_ITEMS_RANK | SEC_COUNT_DELTA | SEC_PARENTS | SEC_DEPTHS | SEC_CHILD_ITEMS_RANK
            | SEC_CHILD_TARGETS | SEC_HEADER_NODES => Some(n - 1),
            SEC_SUBTREE_END => Some(n),
            SEC_CHILD_OFFSETS => Some(n + 1),
            SEC_HEADER_OFFSETS => Some(num_ranks + 1),
            id if (SEC_METRIC_BASE..SEC_METRIC_BASE + 10).contains(&id) => Some(n),
            _ => None,
        }
    };

    let mut s = MappedSections {
        items_rank: SectionView::empty(),
        count_delta: SectionView::empty(),
        parents: SectionView::empty(),
        depths: SectionView::empty(),
        subtree_end: SectionView::empty(),
        child_offsets: SectionView::empty(),
        child_items_rank: SectionView::empty(),
        child_targets: SectionView::empty(),
        header_offsets: SectionView::empty(),
        header_nodes: SectionView::empty(),
        metric_raw: [None; 10],
    };
    let mut seen_required = 0u16;
    let mut prev_id = 0u8;
    let mut cursor = toc_end;
    for e in entry_bytes.chunks_exact(32) {
        let (id, codec, width, flags) = (e[0], e[1], e[2], e[3]);
        let sect_crc = u32::from_le_bytes(e[4..8].try_into().unwrap());
        let count = u64::from_le_bytes(e[8..16].try_into().unwrap()) as usize;
        let off = u64::from_le_bytes(e[16..24].try_into().unwrap()) as usize;
        let len = u64::from_le_bytes(e[24..32].try_into().unwrap()) as usize;
        if id <= prev_id {
            return corrupt(format!("section ids not strictly ascending at id {id}"));
        }
        prev_id = id;
        if flags != 0 {
            return corrupt(format!("section {id}: unknown flags {flags:#04x}"));
        }
        let Some(want_count) = expected_count(id) else {
            return corrupt(format!("unknown section id {id}"));
        };
        if count != want_count {
            return corrupt(format!(
                "section {id}: {count} elements, expected {want_count}"
            ));
        }
        let is_metric = id >= SEC_METRIC_BASE;
        let len_ok = match codec {
            CODEC_BITPACK if !is_metric => {
                width <= bitpack::MAX_PACKED_WIDTH && len == bitpack::payload_len(count, width)
            }
            CODEC_U64 if !is_metric => width == 64 && len == count * 8,
            CODEC_F64 if is_metric => width == 64 && len == count * 8,
            CODEC_F32Q if is_metric => width == 32 && len == count * 4,
            _ => false,
        };
        if !len_ok {
            return corrupt(format!(
                "section {id}: codec {codec}/width {width}/len {len} inconsistent for \
                 {count} elements"
            ));
        }
        if off % V4_ALIGN != 0 || off < cursor {
            return corrupt(format!("section {id}: misaligned or overlapping offset {off}"));
        }
        let Some(payload) = bytes.get(off..off + len) else {
            return corrupt(format!("section {id}: extent {off}+{len} out of bounds"));
        };
        cursor = align_up(off + len);
        if verify_payloads {
            let mut crc = Crc32::new();
            crc.update(payload);
            if sect_crc != crc.finish() {
                return corrupt(format!("section {id}: payload checksum mismatch"));
            }
        } else {
            // Trusted open: the extent check above is all we need from
            // the payload; silence the otherwise-unused binding.
            let _ = payload;
        }
        let view = SectionView {
            off,
            len,
            count,
            width,
            codec,
        };
        match id {
            SEC_ITEMS_RANK => s.items_rank = view,
            SEC_COUNT_DELTA => s.count_delta = view,
            SEC_PARENTS => s.parents = view,
            SEC_DEPTHS => s.depths = view,
            SEC_SUBTREE_END => s.subtree_end = view,
            SEC_CHILD_OFFSETS => s.child_offsets = view,
            SEC_CHILD_ITEMS_RANK => s.child_items_rank = view,
            SEC_CHILD_TARGETS => s.child_targets = view,
            SEC_HEADER_OFFSETS => s.header_offsets = view,
            SEC_HEADER_NODES => s.header_nodes = view,
            // Only raw f64 sections are servable zero-copy; quantized
            // columns are CRC-checked above and otherwise ignored (the
            // exact derivation is always available).
            _ => {
                if codec == CODEC_F64 {
                    s.metric_raw[(id - SEC_METRIC_BASE) as usize] = Some(view);
                }
            }
        }
        if id <= SEC_HEADER_NODES {
            seen_required |= 1 << id;
        }
    }
    if seen_required != 0b111_1111_1110 {
        return corrupt("missing required structure sections");
    }
    if cursor != bytes.len() {
        return corrupt(format!(
            "{} trailing bytes after last section",
            bytes.len() - cursor
        ));
    }

    Ok(V4Parsed {
        order,
        num_transactions,
        num_rows,
        representable,
        has_vocab: flag == 1,
        vocab,
        sections: s,
    })
}

/// The semantic sweep over a [`parse_v4`] image: one pass with an
/// open-ancestor stack proving the packed columns describe a well-formed
/// DFS-preorder trie — parents precede and enclose children, depths
/// chain, counts are antimonotone (deltas never underflow), subtree
/// ranges nest, both CSRs are exactly the re-derivable ones (bijections
/// onto the non-root rows). Returns the representable-rule count. After
/// this, every mapped accessor is panic-free on this image — a forged
/// file that passed the CRCs still cannot cause unbounded parent walks or
/// out-of-range decode-table reads.
fn validate_v4_structure(bytes: &[u8], p: &V4Parsed) -> LoadResult<usize> {
    let n = p.num_rows;
    let s = &p.sections;
    let rank_to_item = p.order.frequent_items();
    let num_ranks = rank_to_item.len();
    let root_count = p.num_transactions as u64;

    if s.subtree_end.get(bytes, 0) != n as u64 {
        return corrupt("root subtree range does not cover the file");
    }
    // (index, exclusive end, count) of each open ancestor, root upward.
    let mut stack: Vec<(usize, u64, u64)> = vec![(0, n as u64, root_count)];
    let mut representable = 0usize;
    for i in 1..n {
        while stack.last().is_some_and(|&(_, end, _)| end <= i as u64) {
            stack.pop();
        }
        let &(top, top_end, top_count) = stack.last().expect("root range covers every row");
        let par = s.parents.get(bytes, i - 1);
        if par != top as u64 {
            return corrupt(format!(
                "node {i}: parent {par} is not the open ancestor (not DFS preorder)"
            ));
        }
        let depth = s.depths.get(bytes, i - 1);
        if depth != stack.len() as u64 || depth > u16::MAX as u64 {
            return corrupt(format!("node {i}: depth {depth} breaks the parent chain"));
        }
        let delta = s.count_delta.get(bytes, i - 1);
        if delta > top_count {
            return corrupt(format!("node {i}: count delta {delta} exceeds parent count"));
        }
        let end = s.subtree_end.get(bytes, i);
        if end <= i as u64 || end > top_end {
            return corrupt(format!("node {i}: subtree end {end} not nested"));
        }
        if s.items_rank.get(bytes, i - 1) >= num_ranks as u64 {
            return corrupt(format!("node {i}: item rank out of range"));
        }
        representable += depth as usize - 1;
        stack.push((i, end, top_count - delta));
    }

    // Child CSR: exactly the one re-derivable from parents — offsets
    // cover all n-1 edges, every edge's target names this owner as its
    // parent and carries the edge's item, siblings strictly item-sorted.
    // Per-slice distinctness + the n-1 total makes the targets a
    // bijection onto rows 1..n.
    let co = &s.child_offsets;
    if co.get(bytes, 0) != 0 || co.get(bytes, n) != (n - 1) as u64 {
        return corrupt("child CSR offsets do not cover the edge list");
    }
    for i in 0..n {
        let lo = co.get(bytes, i);
        let hi = co.get(bytes, i + 1);
        if lo > hi {
            return corrupt(format!("node {i}: child offsets not monotone"));
        }
        let mut prev_item: Option<ItemId> = None;
        for e in lo as usize..hi as usize {
            let t = s.child_targets.get(bytes, e) as usize;
            if t == 0 || t >= n {
                return corrupt(format!("edge {e}: target {t} out of range"));
            }
            if s.parents.get(bytes, t - 1) != i as u64 {
                return corrupt(format!("edge {e}: target {t} is not a child of {i}"));
            }
            let rank = s.child_items_rank.get(bytes, e);
            if rank != s.items_rank.get(bytes, t - 1) {
                return corrupt(format!("edge {e}: item disagrees with target {t}"));
            }
            let item = rank_to_item[rank as usize];
            if prev_item.is_some_and(|p0| p0 >= item) {
                return corrupt(format!("node {i}: children not strictly item-sorted"));
            }
            prev_item = Some(item);
        }
    }

    // Header CSR: per rank, the carrying nodes in strictly ascending
    // preorder; same bijection argument as the child CSR.
    let ho = &s.header_offsets;
    if ho.get(bytes, 0) != 0 || ho.get(bytes, num_ranks) != (n - 1) as u64 {
        return corrupt("header CSR offsets do not cover the node list");
    }
    for r in 0..num_ranks {
        let lo = ho.get(bytes, r);
        let hi = ho.get(bytes, r + 1);
        if lo > hi {
            return corrupt(format!("rank {r}: header offsets not monotone"));
        }
        let mut prev_node = 0u64;
        for e in lo as usize..hi as usize {
            let t = s.header_nodes.get(bytes, e) as usize;
            if t == 0 || t >= n {
                return corrupt(format!("header entry {e}: node {t} out of range"));
            }
            if s.items_rank.get(bytes, t - 1) != r as u64 {
                return corrupt(format!("header entry {e}: node {t} does not carry rank {r}"));
            }
            if t as u64 <= prev_node {
                return corrupt(format!("rank {r}: header nodes not ascending"));
            }
            prev_node = t as u64;
        }
    }

    if representable != p.representable {
        return corrupt(format!(
            "preamble claims {} representable rules, sweep found {representable}",
            p.representable
        ));
    }
    Ok(representable)
}

/// Decode a v4 image into fully owned columns, funneling through
/// [`TrieOfRules::from_columns`] (complete re-validation) and verifying
/// any raw metric sections bit-for-bit against the derivation.
fn load_v4_owned(bytes: &[u8]) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    let p = parse_v4(bytes, true)?;
    // The structural sweep first: it proves the decode below cannot
    // underflow a count or index a parent out of range.
    validate_v4_structure(bytes, &p)?;
    let n = p.num_rows;
    let s = &p.sections;
    let rank_to_item = p.order.frequent_items();

    let mut items: Vec<ItemId> = Vec::with_capacity(n);
    let mut counts: Vec<u64> = Vec::with_capacity(n);
    let mut parents: Vec<NodeIdx> = Vec::with_capacity(n);
    let mut depths: Vec<u16> = Vec::with_capacity(n);
    items.push(ROOT_ITEM);
    counts.push(p.num_transactions as u64);
    parents.push(ROOT);
    depths.push(0);
    for i in 1..n {
        let par = s.parents.get(bytes, i - 1) as usize;
        items.push(rank_to_item[s.items_rank.get(bytes, i - 1) as usize]);
        counts.push(counts[par] - s.count_delta.get(bytes, i - 1));
        parents.push(par as NodeIdx);
        depths.push(s.depths.get(bytes, i - 1) as u16);
    }
    let subtree_end: Vec<NodeIdx> = (0..n)
        .map(|i| s.subtree_end.get(bytes, i) as NodeIdx)
        .collect();
    let child_offsets: Vec<u32> = (0..=n)
        .map(|i| s.child_offsets.get(bytes, i) as u32)
        .collect();
    let child_items: Vec<ItemId> = (0..n - 1)
        .map(|e| rank_to_item[s.child_items_rank.get(bytes, e) as usize])
        .collect();
    let child_targets: Vec<NodeIdx> = (0..n - 1)
        .map(|e| s.child_targets.get(bytes, e) as NodeIdx)
        .collect();
    let num_ranks = rank_to_item.len();
    let header_offsets: Vec<u32> = (0..=num_ranks)
        .map(|r| s.header_offsets.get(bytes, r) as u32)
        .collect();
    let header_nodes: Vec<NodeIdx> = (0..n - 1)
        .map(|e| s.header_nodes.get(bytes, e) as NodeIdx)
        .collect();

    let trie = TrieOfRules::from_columns(
        p.order.clone(),
        p.num_transactions,
        items,
        counts,
        parents,
        depths,
        subtree_end,
        child_offsets,
        child_items,
        child_targets,
        header_offsets,
        header_nodes,
    )?;

    for (slot, &m) in Metric::ALL.iter().enumerate() {
        if let Some(sect) = p.sections.metric_raw[slot] {
            let derived = trie.metric_column(m);
            for i in 0..sect.count {
                let at = sect.off + i * 8;
                let stored = u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap());
                if stored != derived[i].to_bits() {
                    return corrupt(format!(
                        "metric section {m:?} row {i} disagrees with its derivation"
                    ));
                }
            }
        }
    }
    Ok((trie, p.vocab))
}

/// How much of a v4 image [`open_with_mode`] verifies before serving it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpenMode {
    /// Full verification: preamble/TOC/payload CRCs plus the structural
    /// sweep ([`validate_v4_structure`] semantics). O(file) once; after
    /// it, every mapped accessor is panic-free even on a forged file.
    /// The right mode for any file that crossed a trust boundary.
    Validate,
    /// Header verification only: preamble + TOC CRCs, section extent and
    /// formula checks — O(header), independent of file size. Payload
    /// bytes are not touched until queries fault them in. Reserve this
    /// for images this process (or a trusted pipeline) wrote itself via
    /// [`save`]/[`save_with`] + atomic rename — the durability plane's
    /// checkpoints, where the manifest names the exact file and
    /// [`fsio::atomic_write_with`] rules out torn writes. A semantically
    /// corrupt trusted file can return wrong rows (it cannot read out of
    /// bounds — extents are still checked — but nothing proves the
    /// packed values form a trie).
    Trusted,
}

/// Open a snapshot for serving. A v4 file is validated in place (CRC
/// passes + one structural sweep over the packed bytes) and served
/// **zero-copy from an `mmap`** — cold open does no column
/// materialization, so restart cost is O(validation), not O(decode).
/// Older versions (v1–v3) cannot be served in place and fall back to the
/// owned loader over the mapped bytes.
pub fn open(path: &Path) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    open_with(&RealVfs, path)
}

/// [`open`] with [`OpenMode::Trusted`]: header seals only, O(header) cold
/// open — the instant-restart path for self-written checkpoints.
pub fn open_trusted(path: &Path) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    open_with_mode(&RealVfs, path, OpenMode::Trusted)
}

/// [`open`] over an injectable filesystem (the chaos harness exercises
/// this through [`crate::util::fsio::MemVfs`]'s aligned-buffer mmap
/// emulation). Fully validating.
pub fn open_with(vfs: &dyn Vfs, path: &Path) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    open_with_mode(vfs, path, OpenMode::Validate)
}

/// [`open_with`] with an explicit [`OpenMode`].
pub fn open_with_mode(
    vfs: &dyn Vfs,
    path: &Path,
    mode: OpenMode,
) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    let region = vfs.mmap(path).map_err(LoadError::Io)?;
    if region.len() < 8 {
        return corrupt("truncated (missing header)");
    }
    if region[..4] != MAGIC {
        return Err(LoadError::BadMagic);
    }
    let version = u32::from_le_bytes(region[4..8].try_into().unwrap());
    if version != VERSION_V4 {
        // Legacy files cannot be served in place regardless of mode.
        return try_load_from(&mut &region[..]);
    }
    let validate = mode == OpenMode::Validate;
    let p = parse_v4(&region, validate)?;
    let representable = if validate {
        validate_v4_structure(&region, &p)?
    } else {
        p.representable
    };
    let rank_to_item = p.order.frequent_items().to_vec();
    let rank_to_freq: Vec<u64> = rank_to_item.iter().map(|&it| p.order.frequency(it)).collect();
    let cols = MappedColumns::new(
        region,
        p.num_rows,
        p.num_transactions,
        p.has_vocab,
        rank_to_item,
        rank_to_freq,
        p.sections,
    );
    let trie = TrieOfRules::from_mapped(p.order, p.num_transactions, representable, Arc::new(cols));
    Ok((trie, p.vocab))
}

// -- incremental delta sidecar -------------------------------------------

/// Persist the pending (uncompacted) transaction tail of an incremental
/// service next to its frozen snapshot (`SNAPSHOT` writes the snapshot
/// plus this sidecar). Format, little-endian:
///
/// ```text
/// magic "TORD" | version u32 (= 2) | epoch u64 | minsup f64 (bit pattern)
/// num_tx u32 | per tx: len u32, item ids u32…
/// crc32 u32  (IEEE, over every preceding byte; absent in legacy v1)
/// ```
///
/// Restoring a service: the snapshot does **not** carry the base
/// transaction database the incremental store needs, so restore = re-run
/// the pipeline on the base source and fold the sidecar back in via
/// [`crate::trie::delta::IncrementalTrie::ingest`] — that is what
/// `tor query|serve --replay-delta FILE` does. With `--wal-dir` set the
/// durability plane's checkpoint + WAL recovery subsumes this
/// (DESIGN.md §16); the sidecar remains for WAL-less operation.
pub fn save_delta(path: &Path, epoch: u64, minsup: f64, pending: &[Vec<u32>]) -> Result<()> {
    save_delta_with(&RealVfs, path, epoch, minsup, pending)
}

/// [`save_delta`] over an injectable filesystem. Crash-safe: write-temp +
/// fsync + atomic rename.
pub fn save_delta_with(
    vfs: &dyn Vfs,
    path: &Path,
    epoch: u64,
    minsup: f64,
    pending: &[Vec<u32>],
) -> Result<()> {
    fsio::atomic_write_with(vfs, path, |w| {
        let mut cw = Crc32Writer::new(&mut *w);
        cw.write_all(&DELTA_MAGIC)?;
        cw.write_all(&DELTA_VERSION.to_le_bytes())?;
        cw.write_all(&epoch.to_le_bytes())?;
        cw.write_all(&minsup.to_bits().to_le_bytes())?;
        cw.write_all(&(pending.len() as u32).to_le_bytes())?;
        for tx in pending {
            cw.write_all(&(tx.len() as u32).to_le_bytes())?;
            for &it in tx {
                cw.write_all(&it.to_le_bytes())?;
            }
        }
        let crc = cw.digest();
        w.write_all(&crc.to_le_bytes())?;
        Ok(())
    })
    .with_context(|| format!("save delta sidecar {}", path.display()))
}

/// Load a delta sidecar: `(epoch, minsup, pending transactions)`.
pub fn load_delta(path: &Path) -> Result<(u64, f64, Vec<Vec<u32>>)> {
    let out = try_load_delta_with(&RealVfs, path).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(out)
}

/// [`load_delta`] with a typed error, over an injectable filesystem.
pub fn try_load_delta_with(vfs: &dyn Vfs, path: &Path) -> LoadResult<(u64, f64, Vec<Vec<u32>>)> {
    let f = vfs.open(path).map_err(LoadError::Io)?;
    let mut r = BufReader::new(f);
    let mut head = [0u8; 8];
    r.read_exact(&mut head)?;
    if head[..4] != DELTA_MAGIC {
        return Err(LoadError::BadMagic);
    }
    let version = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    match version {
        DELTA_VERSION_V1 => load_delta_tail(&mut r),
        DELTA_VERSION => {
            let mut rest = Vec::new();
            r.read_to_end(&mut rest)?;
            let body = check_seal(&head, &rest)?;
            let mut br = body;
            let out = load_delta_tail(&mut br)?;
            if !br.is_empty() {
                return corrupt(format!("{} trailing bytes in sidecar", br.len()));
            }
            Ok(out)
        }
        other => Err(LoadError::BadVersion(other)),
    }
}

fn load_delta_tail<R: Read>(r: &mut R) -> LoadResult<(u64, f64, Vec<Vec<u32>>)> {
    let epoch = read_u64(r)?;
    let minsup = f64::from_bits(read_u64(r)?);
    if !(0.0..=1.0).contains(&minsup) {
        return corrupt(format!("implausible minsup {minsup} in sidecar"));
    }
    let num_tx = read_u32(r)? as usize;
    if num_tx >= 1 << 28 {
        return corrupt(format!("implausible transaction count {num_tx}"));
    }
    let mut pending = Vec::with_capacity(num_tx.min(1 << 16));
    for _ in 0..num_tx {
        let len = read_u32(r)? as usize;
        if len >= 1 << 24 {
            return corrupt(format!("implausible transaction length {len}"));
        }
        let mut tx = Vec::with_capacity(len.min(1 << 16));
        for _ in 0..len {
            tx.push(read_u32(r)?);
        }
        pending.push(tx);
    }
    Ok((epoch, minsup, pending))
}

// -- checkpoint transaction-db dump --------------------------------------

/// Persist a [`TransactionDb`] (vocab + rows) — the piece a snapshot
/// alone lacks to restore an incremental store. Used by the durability
/// plane's checkpoints (`ckpt-<id>.db`). Format, little-endian:
///
/// ```text
/// magic "TORB" | version u32 (= 1)
/// num_names u32 | per name: len u32, utf-8 bytes
/// num_tx u64 | per tx: len u32, item ids u32…
/// crc32 u32  (IEEE, over every preceding byte)
/// ```
pub fn save_db_with(vfs: &dyn Vfs, db: &TransactionDb, path: &Path) -> Result<()> {
    fsio::atomic_write_with(vfs, path, |w| {
        let mut cw = Crc32Writer::new(&mut *w);
        cw.write_all(&DB_MAGIC)?;
        cw.write_all(&DB_VERSION.to_le_bytes())?;
        let vocab = db.vocab();
        cw.write_all(&(vocab.len() as u32).to_le_bytes())?;
        for name in vocab.names() {
            cw.write_all(&(name.len() as u32).to_le_bytes())?;
            cw.write_all(name.as_bytes())?;
        }
        cw.write_all(&(db.num_transactions() as u64).to_le_bytes())?;
        for tx in db.iter() {
            cw.write_all(&(tx.len() as u32).to_le_bytes())?;
            for &it in tx {
                cw.write_all(&it.to_le_bytes())?;
            }
        }
        let crc = cw.digest();
        w.write_all(&crc.to_le_bytes())?;
        Ok(())
    })
    .with_context(|| format!("save transaction db {}", path.display()))
}

/// Load a [`save_db_with`] dump.
pub fn load_db_with(vfs: &dyn Vfs, path: &Path) -> LoadResult<TransactionDb> {
    let f = vfs.open(path).map_err(LoadError::Io)?;
    let mut r = BufReader::new(f);
    let mut head = [0u8; 8];
    r.read_exact(&mut head)?;
    if head[..4] != DB_MAGIC {
        return Err(LoadError::BadMagic);
    }
    let version = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    if version != DB_VERSION {
        return Err(LoadError::BadVersion(version));
    }
    let mut rest = Vec::new();
    r.read_to_end(&mut rest)?;
    let body = check_seal(&head, &rest)?;
    let mut br = body;
    let r = &mut br;
    let num_names = read_u32(r)? as usize;
    if num_names >= 1 << 28 {
        return corrupt(format!("implausible vocab size {num_names}"));
    }
    let mut vocab = Vocab::new();
    for i in 0..num_names {
        let len = read_u32(r)? as usize;
        if len >= 1 << 20 {
            return corrupt(format!("implausible name length {len}"));
        }
        let mut buf = vec![0u8; len];
        r.read_exact(&mut buf)?;
        match String::from_utf8(buf) {
            Ok(s) => {
                vocab.intern(&s);
            }
            Err(_) => return corrupt(format!("vocab entry {i} is not utf-8")),
        }
    }
    let num_tx = read_u64(r)? as usize;
    if num_tx >= 1 << 32 {
        return corrupt(format!("implausible transaction count {num_tx}"));
    }
    let mut builder = TransactionDb::builder(vocab);
    for _ in 0..num_tx {
        let len = read_u32(r)? as usize;
        if len >= 1 << 24 {
            return corrupt(format!("implausible transaction length {len}"));
        }
        let mut tx = Vec::with_capacity(len.min(1 << 16));
        for _ in 0..len {
            tx.push(read_u32(r)?);
        }
        builder.push_ids(tx);
    }
    if !r.is_empty() {
        return corrupt(format!("{} trailing bytes in db dump", r.len()));
    }
    Ok(builder.build())
}

// -- column I/O helpers ---------------------------------------------------

fn write_col_u32(w: &mut impl Write, col: &[u32]) -> Result<()> {
    w.write_all(&(col.len() as u32).to_le_bytes())?;
    for &v in col {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn write_col_u64(w: &mut impl Write, col: &[u64]) -> Result<()> {
    w.write_all(&(col.len() as u32).to_le_bytes())?;
    for &v in col {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn write_col_u16(w: &mut impl Write, col: &[u16]) -> Result<()> {
    w.write_all(&(col.len() as u32).to_le_bytes())?;
    for &v in col {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_col_u32<R: Read>(r: &mut R) -> LoadResult<Vec<u32>> {
    let len = read_u32(r)? as usize;
    if len >= 1 << 30 {
        return corrupt(format!("implausible column length {len}"));
    }
    let mut out = Vec::with_capacity(len.min(1 << 16));
    for _ in 0..len {
        out.push(read_u32(r)?);
    }
    Ok(out)
}

fn read_col_u64<R: Read>(r: &mut R) -> LoadResult<Vec<u64>> {
    let len = read_u32(r)? as usize;
    if len >= 1 << 30 {
        return corrupt(format!("implausible column length {len}"));
    }
    let mut out = Vec::with_capacity(len.min(1 << 16));
    for _ in 0..len {
        out.push(read_u64(r)?);
    }
    Ok(out)
}

fn read_col_u16<R: Read>(r: &mut R) -> LoadResult<Vec<u16>> {
    let len = read_u32(r)? as usize;
    if len >= 1 << 30 {
        return corrupt(format!("implausible column length {len}"));
    }
    let mut out = Vec::with_capacity(len.min(1 << 16));
    for _ in 0..len {
        let mut b = [0u8; 2];
        r.read_exact(&mut b)?;
        out.push(u16::from_le_bytes(b));
    }
    Ok(out)
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::GeneratorConfig;
    use crate::data::transaction::paper_example_db;
    use crate::mining::counts::min_count;
    use crate::mining::fpgrowth::fpgrowth;
    use crate::rules::metrics::Metric;
    use crate::trie::trie::FindOutcome;
    use crate::util::fsio::MemVfs;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("tor_ser_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{tag}.tor"))
    }

    fn build(seed: u64, minsup: f64) -> (crate::data::transaction::TransactionDb, TrieOfRules) {
        let db = GeneratorConfig::tiny(seed).generate();
        let fi = fpgrowth(&db, minsup);
        let order = ItemOrder::new(&db, min_count(minsup, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        (db, trie)
    }

    fn assert_equivalent(a: &TrieOfRules, b: &TrieOfRules) {
        assert_eq!(a.num_nodes(), b.num_nodes());
        assert_eq!(a.num_transactions(), b.num_transactions());
        assert_eq!(a.items_column(), b.items_column());
        assert_eq!(a.counts_column(), b.counts_column());
        assert_eq!(a.parents_column(), b.parents_column());
        assert_eq!(a.subtree_end_column(), b.subtree_end_column());
        assert_eq!(a.child_csr(), b.child_csr());
        assert_eq!(a.header_csr(), b.header_csr());
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let (db, trie) = build(5, 0.05);
        let path = tmpfile("roundtrip");
        save(&trie, Some(db.vocab()), &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        let vocab = vocab.expect("vocab stored");
        assert_eq!(vocab.len(), db.vocab().len());
        assert_equivalent(&trie, &back);
        // Every rule answers identically, metrics included.
        let mut checked = 0;
        trie.for_each_rule(|rule, m| {
            match back.find_rule(rule) {
                FindOutcome::Found(bm) => {
                    assert!((bm.support - m.support).abs() < 1e-15, "{rule}");
                    assert!((bm.confidence - m.confidence).abs() < 1e-15, "{rule}");
                    assert!((bm.lift - m.lift).abs() < 1e-12, "{rule}");
                }
                other => panic!("{rule}: {other:?}"),
            }
            checked += 1;
        });
        assert!(checked > 10);
        // Top-N agrees too.
        let a: Vec<f64> = trie.top_n(Metric::Lift, 5).iter().map(|&(_, v)| v).collect();
        let b: Vec<f64> = back.top_n(Metric::Lift, 5).iter().map(|&(_, v)| v).collect();
        assert_eq!(a, b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_reader_rebuilds_identical_trie() {
        let (db, trie) = build(5, 0.05);
        let path = tmpfile("v1_roundtrip");
        save_v1(&trie, Some(db.vocab()), &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        assert!(vocab.is_some());
        // The v1 path rebuilds through the builder + freeze; the preorder
        // renumbering is canonical, so the columns come back identical.
        assert_equivalent(&trie, &back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_v2_still_loads() {
        let (db, trie) = build(5, 0.05);
        let mut bytes = Vec::new();
        save_v2_to(&trie, Some(db.vocab()), &mut bytes).unwrap();
        let (back, vocab) = try_load_from(&mut &bytes[..]).unwrap();
        assert!(vocab.is_some());
        assert_equivalent(&trie, &back);
    }

    #[test]
    fn roundtrip_without_vocab() {
        let (_, trie) = build(6, 0.06);
        let path = tmpfile("novocab");
        save(&trie, None, &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        assert!(vocab.is_none());
        assert_eq!(back.num_nodes(), trie.num_nodes());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn paper_example_roundtrip() {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        let path = tmpfile("paper");
        save(&trie, Some(db.vocab()), &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        let vocab = vocab.unwrap();
        let name = |s: &str| vocab.get(s).unwrap();
        assert_eq!(back.support_of(&[name("f"), name("c")]), Some(3));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_leaves_no_temp_file_and_survives_fault() {
        let (db, trie) = build(9, 0.05);
        let vfs = MemVfs::new(11);
        let path = Path::new("snaps/a.tor");
        vfs.create_dir_all(Path::new("snaps")).unwrap();
        save_with(&vfs, &trie, Some(db.vocab()), path).unwrap();
        let good = vfs.read(path).unwrap();
        assert!(!vfs.exists(&fsio::tmp_path(path)), "temp file left behind");
        // A faulted re-save must leave the previous snapshot intact.
        vfs.fail_path_containing(Some(".tmp"));
        assert!(save_with(&vfs, &trie, Some(db.vocab()), path).is_err());
        vfs.fail_path_containing(None);
        assert_eq!(vfs.read(path).unwrap(), good);
        let (back, _) = try_load_with(&vfs, path).unwrap();
        assert_equivalent(&trie, &back);
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let path = tmpfile("garbage");
        std::fs::write(&path, b"not a trie file at all").unwrap();
        assert!(load(&path).is_err());
        assert!(matches!(try_load(&path), Err(LoadError::BadMagic)));
        // Truncated real file (all formats).
        let (db, trie) = build(7, 0.06);
        for (tag, saver) in [
            ("full_v4", save as fn(&TrieOfRules, Option<&Vocab>, &Path) -> Result<()>),
            ("full_v1", save_v1),
        ] {
            let full = tmpfile(tag);
            saver(&trie, Some(db.vocab()), &full).unwrap();
            let bytes = std::fs::read(&full).unwrap();
            std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
            assert!(load(&path).is_err(), "{tag} truncation accepted");
            std::fs::remove_file(&full).ok();
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unknown_version_is_badversion_not_corrupt() {
        let (db, trie) = build(7, 0.06);
        let mut bytes = Vec::new();
        save_v2_to(&trie, Some(db.vocab()), &mut bytes).unwrap();
        bytes[4..8].copy_from_slice(&77u32.to_le_bytes());
        match try_load_from(&mut &bytes[..]) {
            Err(LoadError::BadVersion(77)) => {}
            other => panic!("expected BadVersion(77), got {other:?}"),
        }
    }

    #[test]
    fn v1_rejects_corrupt_counts() {
        // Corrupt a node count so it exceeds its parent: loader must refuse.
        let (db, trie) = build(8, 0.06);
        let path = tmpfile("corrupt_v1");
        save_v1(&trie, Some(db.vocab()), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Last 8 bytes = last node's count; blow it up.
        let n = bytes.len();
        bytes[n - 8..].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("exceeds parent"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn delta_sidecar_roundtrip_and_rejection() {
        let path = tmpfile("sidecar");
        let pending: Vec<Vec<u32>> = vec![vec![0, 3, 5], vec![2], vec![1, 4]];
        save_delta(&path, 7, 0.005, &pending).unwrap();
        let (epoch, minsup, back) = load_delta(&path).unwrap();
        assert_eq!(epoch, 7);
        assert!((minsup - 0.005).abs() < 1e-15);
        assert_eq!(back, pending);
        // Garbage and truncation are rejected.
        std::fs::write(&path, b"not a sidecar").unwrap();
        assert!(load_delta(&path).is_err());
        save_delta(&path, 7, 0.005, &pending).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load_delta(&path).is_err());
        // A flipped payload bit fails the sidecar CRC.
        let mut flipped = bytes.clone();
        flipped[12] ^= 0x40;
        std::fs::write(&path, &flipped).unwrap();
        let err = load_delta(&path).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_rejects_tampered_columns() {
        // Flip the tail of the header-nodes column in a legacy (no-CRC)
        // v2 image: the loader re-derives the CSRs from the core columns
        // and must notice the disagreement.
        let (db, trie) = build(8, 0.06);
        let mut bytes = Vec::new();
        save_v2_to(&trie, Some(db.vocab()), &mut bytes).unwrap();
        let n = bytes.len();
        bytes[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = try_load_from(&mut &bytes[..]).unwrap_err();
        assert!(err.to_string().contains("header CSR"), "{err}");
    }

    #[test]
    fn v3_crc_catches_tampering_before_semantics() {
        let (db, trie) = build(8, 0.06);
        let mut bytes = Vec::new();
        save_v3_to(&trie, Some(db.vocab()), &mut bytes).unwrap();
        // Flip one payload bit: rejected with a checksum error (the seal
        // is verified before any semantic validation).
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        let err = try_load_from(&mut &bytes[..]).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // Trailing garbage shifts the trailer and fails the seal too.
        bytes[mid] ^= 0x01;
        bytes.push(0);
        let err = try_load_from(&mut &bytes[..]).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn v3_writer_still_loads_identically() {
        let (db, trie) = build(8, 0.06);
        let mut bytes = Vec::new();
        save_v3_to(&trie, Some(db.vocab()), &mut bytes).unwrap();
        let (back, vocab) = try_load_from(&mut &bytes[..]).unwrap();
        assert!(vocab.is_some());
        assert_equivalent(&trie, &back);
    }

    #[test]
    fn v4_every_single_bit_flip_is_detected_or_harmless() {
        // Exhaustive one-bit corruption sweep over a whole v4 image: every
        // flip must either fail to load (CRCs, layout rules, structural
        // sweep) or — only for bits in alignment padding, which no reader
        // ever dereferences — load a trie identical to the original.
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        let bytes = encode_v4(&trie, Some(db.vocab())).unwrap();
        assert_eq!(bytes.len() % V4_ALIGN, 0);
        let mut detected = 0usize;
        for pos in 0..bytes.len() {
            let mut evil = bytes.clone();
            evil[pos] ^= 1 << (pos % 8);
            match try_load_from(&mut &evil[..]) {
                Err(_) => detected += 1,
                Ok((back, _)) => assert_equivalent(&trie, &back),
            }
        }
        // The overwhelming majority of bytes are load-bearing.
        assert!(detected * 2 > bytes.len(), "{detected}/{}", bytes.len());
    }

    #[test]
    fn v4_truncation_at_every_block_is_rejected() {
        let (db, trie) = build(7, 0.06);
        let bytes = encode_v4(&trie, Some(db.vocab())).unwrap();
        for cut in (0..bytes.len()).step_by(V4_ALIGN) {
            assert!(
                try_load_from(&mut &bytes[..cut]).is_err(),
                "truncation to {cut} accepted"
            );
        }
    }

    #[test]
    fn v4_mmap_open_is_zero_copy_parity_and_cow_resave() {
        let (db, trie) = build(5, 0.05);
        let vfs = MemVfs::new(21);
        vfs.create_dir_all(Path::new("snaps")).unwrap();
        let path = Path::new("snaps/v4.tor");
        save_with(&vfs, &trie, Some(db.vocab()), path).unwrap();
        let image = vfs.read(path).unwrap();

        let (mapped, vocab) = open_with(&vfs, path).unwrap();
        assert!(vocab.is_some());
        assert_eq!(mapped.backend_name(), "mmap");
        assert_eq!(mapped.mapped_bytes(), image.len());
        assert_equivalent(&trie, &mapped);
        for &m in Metric::ALL.iter() {
            assert_eq!(trie.metric_column(m), mapped.metric_column(m), "{m:?}");
        }
        assert_eq!(trie.top_n(Metric::Lift, 8), mapped.top_n(Metric::Lift, 8));

        // Re-saving the mapped view is a byte copy of the image, not a
        // re-encode.
        let path2 = Path::new("snaps/v4-copy.tor");
        save_with(&vfs, &mapped, Some(db.vocab()), path2).unwrap();
        assert_eq!(vfs.read(path2).unwrap(), image);

        // Vocab-presence mismatch falls back to a clean re-encode that the
        // owned writer would produce.
        let path3 = Path::new("snaps/v4-novocab.tor");
        save_with(&vfs, &mapped, None, path3).unwrap();
        assert_eq!(vfs.read(path3).unwrap(), encode_v4(&trie, None).unwrap());
    }

    #[test]
    fn v4_metric_sections_roundtrip_raw_and_quantized() {
        let (db, trie) = build(6, 0.05);
        let omit = encode_v4(&trie, Some(db.vocab())).unwrap();
        for mode in [MetricMode::Raw, MetricMode::Quantized] {
            let bytes = encode_v4_opts(&trie, Some(db.vocab()), mode).unwrap();
            assert!(bytes.len() > omit.len());
            // Raw sections are verified bit-for-bit against the
            // derivation; quantized ones are CRC-checked and ignored.
            let (back, _) = try_load_from(&mut &bytes[..]).unwrap();
            assert_equivalent(&trie, &back);
            for &m in Metric::ALL.iter() {
                assert_eq!(trie.metric_column(m), back.metric_column(m));
            }
        }
    }

    #[test]
    fn trusted_open_serves_identically_and_checks_only_the_header_seals() {
        let (db, trie) = build(9, 0.05);
        let vfs = MemVfs::new(33);
        let path = Path::new("trusted.tor");
        save_with(&vfs, &trie, Some(db.vocab()), path).unwrap();
        let image = vfs.read(path).unwrap();

        // Pristine file: trusted == validating, including the stored
        // representable count (never re-swept in trusted mode).
        let (mapped, vocab) = open_with_mode(&vfs, path, OpenMode::Trusted).unwrap();
        assert!(vocab.is_some());
        assert_eq!(mapped.backend_name(), "mmap");
        assert_equivalent(&trie, &mapped);
        assert_eq!(
            mapped.num_representable_rules(),
            trie.num_representable_rules()
        );

        // A flipped bit in the preamble or TOC blocks is still rejected
        // in trusted mode (those seals are always verified). Byte 9 sits
        // in the first preamble varint; the first-section offset minus
        // one aligned block lands inside the TOC entries.
        let parsed = parse_v4(&image, true).unwrap();
        let first_payload = parsed.sections.items_rank.off;
        for byte in [9usize, first_payload - V4_ALIGN] {
            let mut tampered = image.clone();
            tampered[byte] ^= 1;
            fsio::atomic_write_with(&vfs, path, |w| w.write_all(&tampered)).unwrap();
            assert!(
                open_with_mode(&vfs, path, OpenMode::Trusted).is_err(),
                "trusted open accepted a header flip at byte {byte}"
            );
        }
        // …while the same payload flip that `Validate` rejects is the
        // documented trusted-mode gap (payload bytes are never touched
        // at open). This pins the trust boundary, not a desirable
        // behavior: never use Trusted on files from outside the process.
        let mut tampered = image.clone();
        tampered[first_payload] ^= 1;
        fsio::atomic_write_with(&vfs, path, |w| w.write_all(&tampered)).unwrap();
        assert!(matches!(
            open_with_mode(&vfs, path, OpenMode::Validate),
            Err(LoadError::Corrupt(_))
        ));
        assert!(open_with_mode(&vfs, path, OpenMode::Trusted).is_ok());
    }

    #[test]
    fn open_falls_back_to_owned_for_legacy_versions() {
        let (db, trie) = build(7, 0.05);
        let vfs = MemVfs::new(5);
        let path = Path::new("legacy.tor");
        let mut bytes = Vec::new();
        save_v3_to(&trie, Some(db.vocab()), &mut bytes).unwrap();
        fsio::atomic_write_with(&vfs, path, |w| w.write_all(&bytes)).unwrap();
        let (back, vocab) = open_with(&vfs, path).unwrap();
        assert!(vocab.is_some());
        assert_eq!(back.backend_name(), "owned");
        assert_equivalent(&trie, &back);
    }

    #[test]
    fn db_dump_roundtrips_and_rejects_corruption() {
        let db = paper_example_db();
        let vfs = MemVfs::new(3);
        let path = Path::new("ckpt-1.db");
        save_db_with(&vfs, &db, path).unwrap();
        let back = load_db_with(&vfs, path).unwrap();
        assert_eq!(back.num_transactions(), db.num_transactions());
        assert_eq!(back.vocab().len(), db.vocab().len());
        for (a, b) in db.iter().zip(back.iter()) {
            assert_eq!(a, b);
        }
        let len = vfs.read(path).unwrap().len();
        vfs.flip_bit(path, len / 2, 3);
        assert!(load_db_with(&vfs, path).is_err());
    }
}
