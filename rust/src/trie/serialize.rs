//! Trie persistence — the feature the paper's amortization argument
//! implies ("creating a ruleset is typically a one-time task"): build the
//! Trie of Rules once, save it, and serve queries from the saved structure
//! without re-mining.
//!
//! Versioned little-endian binary format. **v3** (current) writes the
//! frozen columnar layout directly — one length-prefixed column per array
//! — and seals the file with a CRC32 trailer so a torn or bit-flipped
//! snapshot is rejected before any semantic validation:
//!
//! ```text
//! magic "TOR\x01" | version u32 (= 3)
//! num_transactions u64 | min_count u64
//! num_items u32 | freqs: num_items × u64
//! vocab flag u8 | if 1: num_items × (len u32, utf-8 bytes)
//! columns, each prefixed with its u32 element count, preorder row 0 = root:
//!   items u32[] | counts u64[] | parents u32[] | depths u16[]
//!   subtree_end u32[]
//!   child_offsets u32[] | child_items u32[] | child_targets u32[]
//!   header_offsets u32[] | header_nodes u32[]
//! crc32 u32  (IEEE, over every preceding byte incl. magic)
//! ```
//!
//! Metric columns are *derived* state (pure functions of counts, parent
//! counts and item frequencies) and are recomputed on load rather than
//! stored. The derived structural columns (subtree ranges, both CSRs) are
//! stored *and* re-derived on load; any disagreement rejects the file.
//!
//! **v2** (same body, no trailer) and the **v1** node-record format
//! (`num_nodes u32` + `(item u32, parent u32, count u64)` triples in
//! parent-before-child order) are still read; v1 files rebuild through
//! [`TrieBuilder`] and freeze, and can still be written via [`save_v1`]
//! for downgrade/interop.
//!
//! Durability (DESIGN.md §16): every path-level writer here goes through
//! write-temp + `sync_all` + atomic rename ([`fsio::atomic_write_with`]),
//! so a crash mid-save can never destroy the previous good file, and all
//! writers/loaders are additionally exposed as `*_with` variants over the
//! injectable [`Vfs`] so the chaos harness can exercise them against
//! simulated torn writes and I/O faults. Loaders report typed
//! [`LoadError`]s — [`LoadError::Corrupt`] (bad CRC, truncation, failed
//! re-derivation) is distinguished from [`LoadError::BadVersion`] — and
//! never panic on malformed input (fuzzed in
//! `rust/tests/serialization_golden.rs`).
//!
//! Because the frozen trie is preorder-renumbered with item-sorted
//! siblings and the header is a rank-indexed CSR (no hash-map iteration
//! anywhere), two builds from identical input serialize to identical
//! bytes — tested in `rust/tests/freeze.rs`.

use std::io::{BufReader, Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::data::transaction::TransactionDb;
use crate::data::vocab::Vocab;
use crate::mining::counts::ItemOrder;
use crate::trie::builder::TrieBuilder;
use crate::trie::trie::TrieOfRules;
use crate::util::crc32::Crc32Writer;
use crate::util::fsio::{self, RealVfs, Vfs};

const MAGIC: [u8; 4] = *b"TOR\x01";
const VERSION_V1: u32 = 1;
const VERSION_V2: u32 = 2;
const VERSION_V3: u32 = 3;

/// Magic of the incremental delta sidecar (`<snapshot>.delta`).
const DELTA_MAGIC: [u8; 4] = *b"TORD";
const DELTA_VERSION_V1: u32 = 1;
const DELTA_VERSION: u32 = 2;

/// Magic of the checkpoint transaction-db dump (`ckpt-<id>.db`).
const DB_MAGIC: [u8; 4] = *b"TORB";
const DB_VERSION: u32 = 1;

// -- typed load errors ----------------------------------------------------

/// Why a persisted artifact failed to load. `Corrupt` (bad CRC, torn
/// frame, failed integrity re-derivation) is deliberately distinct from
/// `BadVersion` (well-formed file from a different format era): recovery
/// treats the former as a damaged artifact to skip and the latter as an
/// operator error.
#[derive(Debug)]
pub enum LoadError {
    /// The file is not one of ours at all.
    BadMagic,
    /// Recognized magic, unsupported format version.
    BadVersion(u32),
    /// Truncated, checksum-mismatched, or semantically inconsistent.
    Corrupt(String),
    /// The underlying I/O failed (open/read error, not EOF).
    Io(std::io::Error),
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::BadMagic => write!(f, "bad magic (not a Trie-of-Rules artifact)"),
            LoadError::BadVersion(v) => write!(f, "unsupported version {v}"),
            LoadError::Corrupt(msg) => write!(f, "corrupt artifact: {msg}"),
            LoadError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for LoadError {}

impl From<std::io::Error> for LoadError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            LoadError::Corrupt("truncated (unexpected end of file)".to_string())
        } else {
            LoadError::Io(e)
        }
    }
}

impl From<anyhow::Error> for LoadError {
    fn from(e: anyhow::Error) -> Self {
        LoadError::Corrupt(format!("{e:#}"))
    }
}

type LoadResult<T> = std::result::Result<T, LoadError>;

fn corrupt<T>(msg: impl Into<String>) -> LoadResult<T> {
    Err(LoadError::Corrupt(msg.into()))
}

// -- snapshot save --------------------------------------------------------

/// Save a trie (and optionally its vocabulary) to `path` in the current
/// (v3, columnar + CRC trailer) format. Crash-safe: write-temp + fsync +
/// atomic rename.
pub fn save(trie: &TrieOfRules, vocab: Option<&Vocab>, path: &Path) -> Result<()> {
    save_with(&RealVfs, trie, vocab, path)
}

/// [`save`] over an injectable filesystem.
pub fn save_with(
    vfs: &dyn Vfs,
    trie: &TrieOfRules,
    vocab: Option<&Vocab>,
    path: &Path,
) -> Result<()> {
    fsio::atomic_write_with(vfs, path, |mut w| save_to(trie, vocab, &mut w).map_err(to_io))
        .with_context(|| format!("save snapshot {}", path.display()))
}

fn to_io(e: anyhow::Error) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::Other, format!("{e:#}"))
}

/// Save in v3 format to any writer (in-memory determinism tests use a
/// `Vec<u8>`).
pub fn save_to(trie: &TrieOfRules, vocab: Option<&Vocab>, w: &mut impl Write) -> Result<()> {
    let mut cw = Crc32Writer::new(&mut *w);
    write_body(trie, vocab, VERSION_V3, &mut cw)?;
    let crc = cw.digest();
    w.write_all(&crc.to_le_bytes())?;
    Ok(())
}

/// Save in the legacy v2 format (no CRC trailer) — interop/downgrade and
/// the loader-hardening tests.
pub fn save_v2_to(trie: &TrieOfRules, vocab: Option<&Vocab>, w: &mut impl Write) -> Result<()> {
    write_body(trie, vocab, VERSION_V2, w)
}

fn write_body(
    trie: &TrieOfRules,
    vocab: Option<&Vocab>,
    version: u32,
    w: &mut impl Write,
) -> Result<()> {
    write_preamble(trie, vocab, version, w)?;
    write_col_u32(w, trie.items_column())?;
    write_col_u64(w, trie.counts_column())?;
    write_col_u32(w, trie.parents_column())?;
    write_col_u16(w, trie.depths_column())?;
    write_col_u32(w, trie.subtree_end_column())?;
    let (child_offsets, child_items, child_targets) = trie.child_csr();
    write_col_u32(w, child_offsets)?;
    write_col_u32(w, child_items)?;
    write_col_u32(w, child_targets)?;
    let (header_offsets, header_nodes) = trie.header_csr();
    write_col_u32(w, header_offsets)?;
    write_col_u32(w, header_nodes)?;
    Ok(())
}

/// Save in the legacy v1 node-record format (downgrade/interop path; new
/// writes should use [`save`]). Crash-safe like [`save`].
pub fn save_v1(trie: &TrieOfRules, vocab: Option<&Vocab>, path: &Path) -> Result<()> {
    fsio::atomic_write_with(&RealVfs, path, |mut w| {
        save_v1_to(trie, vocab, &mut w).map_err(to_io)
    })
    .with_context(|| format!("save v1 snapshot {}", path.display()))
}

/// v1 body writer (shared by [`save_v1`] and the golden-fixture tests).
pub fn save_v1_to(trie: &TrieOfRules, vocab: Option<&Vocab>, w: &mut impl Write) -> Result<()> {
    write_preamble(trie, vocab, VERSION_V1, w)?;
    let nodes: Vec<_> = trie.raw_nodes().collect();
    w.write_all(&(nodes.len() as u32).to_le_bytes())?;
    for (item, parent, count) in nodes {
        w.write_all(&item.to_le_bytes())?;
        w.write_all(&parent.to_le_bytes())?;
        w.write_all(&count.to_le_bytes())?;
    }
    Ok(())
}

fn write_preamble(
    trie: &TrieOfRules,
    vocab: Option<&Vocab>,
    version: u32,
    w: &mut impl Write,
) -> Result<()> {
    w.write_all(&MAGIC)?;
    w.write_all(&version.to_le_bytes())?;
    w.write_all(&(trie.num_transactions() as u64).to_le_bytes())?;
    w.write_all(&trie.order().min_count_used().to_le_bytes())?;
    let freqs = trie.order().frequencies();
    w.write_all(&(freqs.len() as u32).to_le_bytes())?;
    for &f0 in freqs {
        w.write_all(&f0.to_le_bytes())?;
    }
    match vocab {
        Some(v) => {
            anyhow::ensure!(
                v.len() == freqs.len(),
                "vocab size {} != item count {}",
                v.len(),
                freqs.len()
            );
            w.write_all(&[1u8])?;
            for name in v.names() {
                w.write_all(&(name.len() as u32).to_le_bytes())?;
                w.write_all(name.as_bytes())?;
            }
        }
        None => w.write_all(&[0u8])?,
    }
    Ok(())
}

// -- snapshot load --------------------------------------------------------

/// Load a trie (and its vocabulary, when stored) from `path`. Reads the
/// current v3 (CRC-sealed) format plus legacy v2 and v1.
pub fn load(path: &Path) -> Result<(TrieOfRules, Option<Vocab>)> {
    let out = try_load(path).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(out)
}

/// [`load`] with a typed error.
pub fn try_load(path: &Path) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    try_load_with(&RealVfs, path)
}

/// [`try_load`] over an injectable filesystem.
pub fn try_load_with(vfs: &dyn Vfs, path: &Path) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    let f = vfs.open(path).map_err(LoadError::Io)?;
    let mut r = BufReader::new(f);
    try_load_from(&mut r)
}

/// Parse a snapshot from any reader (typed errors, never panics on
/// malformed input). For v3 the CRC trailer is verified *before* any
/// semantic validation, so a torn or bit-flipped file reports a checksum
/// failure rather than a misleading shape error.
pub fn try_load_from<R: Read>(r: &mut R) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    let mut head = [0u8; 8];
    r.read_exact(&mut head)?;
    if head[..4] != MAGIC {
        return Err(LoadError::BadMagic);
    }
    let version = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    match version {
        VERSION_V1 | VERSION_V2 => load_tail(r, version),
        VERSION_V3 => {
            let mut rest = Vec::new();
            r.read_to_end(&mut rest)?;
            let body = check_seal(&head, &rest)?;
            let mut br = body;
            let out = load_tail(&mut br, version)?;
            if !br.is_empty() {
                return corrupt(format!("{} trailing bytes after body", br.len()));
            }
            Ok(out)
        }
        other => Err(LoadError::BadVersion(other)),
    }
}

/// Verify a `crc32(head ++ body)` trailer; returns the body slice.
fn check_seal<'a>(head: &[u8], rest: &'a [u8]) -> LoadResult<&'a [u8]> {
    if rest.len() < 4 {
        return corrupt("truncated (missing checksum trailer)");
    }
    let (body, trailer) = rest.split_at(rest.len() - 4);
    let stored = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    let mut crc = crate::util::crc32::Crc32::new();
    crc.update(head);
    crc.update(body);
    let digest = crc.finish();
    if stored != digest {
        return corrupt(format!(
            "checksum mismatch: stored {stored:#010x}, computed {digest:#010x}"
        ));
    }
    Ok(body)
}

/// Everything after magic+version: preamble, vocab, then the
/// version-specific body.
fn load_tail<R: Read>(r: &mut R, version: u32) -> LoadResult<(TrieOfRules, Option<Vocab>)> {
    let num_transactions = read_u64(r)? as usize;
    let min_count = read_u64(r)?;
    let num_items = read_u32(r)? as usize;
    if num_items >= 1 << 28 {
        return corrupt(format!("implausible item count {num_items}"));
    }
    let mut freqs = Vec::with_capacity(num_items.min(1 << 16));
    for _ in 0..num_items {
        freqs.push(read_u64(r)?);
    }
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    if flag[0] > 1 {
        return corrupt(format!("bad vocab flag {}", flag[0]));
    }
    let vocab = if flag[0] == 1 {
        let mut v = Vocab::new();
        for i in 0..num_items {
            let len = read_u32(r)? as usize;
            if len >= 1 << 20 {
                return corrupt(format!("implausible name length {len}"));
            }
            let mut buf = vec![0u8; len];
            r.read_exact(&mut buf)?;
            let name = match String::from_utf8(buf) {
                Ok(s) => s,
                Err(_) => return corrupt(format!("item {i} name is not utf-8")),
            };
            v.intern(&name);
        }
        Some(v)
    } else {
        None
    };
    let order = ItemOrder::from_frequencies(freqs, min_count);
    let trie = match version {
        VERSION_V1 => load_v1_body(r, order, num_transactions)?,
        _ => load_v2_body(r, order, num_transactions)?,
    };
    Ok((trie, vocab))
}

fn load_v1_body<R: Read>(
    r: &mut R,
    order: ItemOrder,
    num_transactions: usize,
) -> LoadResult<TrieOfRules> {
    let num_nodes = read_u32(r)? as usize;
    if num_nodes >= 1 << 30 {
        return corrupt(format!("implausible node count {num_nodes}"));
    }
    let mut raw = Vec::with_capacity(num_nodes.min(1 << 16));
    for _ in 0..num_nodes {
        let item = read_u32(r)?;
        let parent = read_u32(r)?;
        let count = read_u64(r)?;
        raw.push((item, parent, count));
    }
    Ok(TrieBuilder::from_raw_nodes(order, num_transactions, &raw)?.freeze())
}

fn load_v2_body<R: Read>(
    r: &mut R,
    order: ItemOrder,
    num_transactions: usize,
) -> LoadResult<TrieOfRules> {
    let items = read_col_u32(r)?;
    let n = items.len();
    if n < 1 {
        return corrupt("empty items column");
    }
    let counts = read_col_u64(r)?;
    let parents = read_col_u32(r)?;
    let depths = read_col_u16(r)?;
    let subtree_end = read_col_u32(r)?;
    let child_offsets = read_col_u32(r)?;
    let child_items = read_col_u32(r)?;
    let child_targets = read_col_u32(r)?;
    let header_offsets = read_col_u32(r)?;
    let header_nodes = read_col_u32(r)?;
    // Shape checks before semantic validation.
    for (name, len, want) in [
        ("counts", counts.len(), n),
        ("parents", parents.len(), n),
        ("depths", depths.len(), n),
        ("subtree_end", subtree_end.len(), n),
        ("child_offsets", child_offsets.len(), n + 1),
        ("child_items", child_items.len(), n - 1),
        ("child_targets", child_targets.len(), n - 1),
        ("header_offsets", header_offsets.len(), order.num_frequent() + 1),
        ("header_nodes", header_nodes.len(), n - 1),
    ] {
        if len != want {
            return corrupt(format!("column {name}: {len} entries, expected {want}"));
        }
    }
    Ok(TrieOfRules::from_columns(
        order,
        num_transactions,
        items,
        counts,
        parents,
        depths,
        subtree_end,
        child_offsets,
        child_items,
        child_targets,
        header_offsets,
        header_nodes,
    )?)
}

// -- incremental delta sidecar -------------------------------------------

/// Persist the pending (uncompacted) transaction tail of an incremental
/// service next to its frozen snapshot (`SNAPSHOT` writes the snapshot
/// plus this sidecar). Format, little-endian:
///
/// ```text
/// magic "TORD" | version u32 (= 2) | epoch u64 | minsup f64 (bit pattern)
/// num_tx u32 | per tx: len u32, item ids u32…
/// crc32 u32  (IEEE, over every preceding byte; absent in legacy v1)
/// ```
///
/// Restoring a service: the snapshot does **not** carry the base
/// transaction database the incremental store needs, so restore = re-run
/// the pipeline on the base source and fold the sidecar back in via
/// [`crate::trie::delta::IncrementalTrie::ingest`] — that is what
/// `tor query|serve --replay-delta FILE` does. With `--wal-dir` set the
/// durability plane's checkpoint + WAL recovery subsumes this
/// (DESIGN.md §16); the sidecar remains for WAL-less operation.
pub fn save_delta(path: &Path, epoch: u64, minsup: f64, pending: &[Vec<u32>]) -> Result<()> {
    save_delta_with(&RealVfs, path, epoch, minsup, pending)
}

/// [`save_delta`] over an injectable filesystem. Crash-safe: write-temp +
/// fsync + atomic rename.
pub fn save_delta_with(
    vfs: &dyn Vfs,
    path: &Path,
    epoch: u64,
    minsup: f64,
    pending: &[Vec<u32>],
) -> Result<()> {
    fsio::atomic_write_with(vfs, path, |w| {
        let mut cw = Crc32Writer::new(&mut *w);
        cw.write_all(&DELTA_MAGIC)?;
        cw.write_all(&DELTA_VERSION.to_le_bytes())?;
        cw.write_all(&epoch.to_le_bytes())?;
        cw.write_all(&minsup.to_bits().to_le_bytes())?;
        cw.write_all(&(pending.len() as u32).to_le_bytes())?;
        for tx in pending {
            cw.write_all(&(tx.len() as u32).to_le_bytes())?;
            for &it in tx {
                cw.write_all(&it.to_le_bytes())?;
            }
        }
        let crc = cw.digest();
        w.write_all(&crc.to_le_bytes())?;
        Ok(())
    })
    .with_context(|| format!("save delta sidecar {}", path.display()))
}

/// Load a delta sidecar: `(epoch, minsup, pending transactions)`.
pub fn load_delta(path: &Path) -> Result<(u64, f64, Vec<Vec<u32>>)> {
    let out = try_load_delta_with(&RealVfs, path).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(out)
}

/// [`load_delta`] with a typed error, over an injectable filesystem.
pub fn try_load_delta_with(vfs: &dyn Vfs, path: &Path) -> LoadResult<(u64, f64, Vec<Vec<u32>>)> {
    let f = vfs.open(path).map_err(LoadError::Io)?;
    let mut r = BufReader::new(f);
    let mut head = [0u8; 8];
    r.read_exact(&mut head)?;
    if head[..4] != DELTA_MAGIC {
        return Err(LoadError::BadMagic);
    }
    let version = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    match version {
        DELTA_VERSION_V1 => load_delta_tail(&mut r),
        DELTA_VERSION => {
            let mut rest = Vec::new();
            r.read_to_end(&mut rest)?;
            let body = check_seal(&head, &rest)?;
            let mut br = body;
            let out = load_delta_tail(&mut br)?;
            if !br.is_empty() {
                return corrupt(format!("{} trailing bytes in sidecar", br.len()));
            }
            Ok(out)
        }
        other => Err(LoadError::BadVersion(other)),
    }
}

fn load_delta_tail<R: Read>(r: &mut R) -> LoadResult<(u64, f64, Vec<Vec<u32>>)> {
    let epoch = read_u64(r)?;
    let minsup = f64::from_bits(read_u64(r)?);
    if !(0.0..=1.0).contains(&minsup) {
        return corrupt(format!("implausible minsup {minsup} in sidecar"));
    }
    let num_tx = read_u32(r)? as usize;
    if num_tx >= 1 << 28 {
        return corrupt(format!("implausible transaction count {num_tx}"));
    }
    let mut pending = Vec::with_capacity(num_tx.min(1 << 16));
    for _ in 0..num_tx {
        let len = read_u32(r)? as usize;
        if len >= 1 << 24 {
            return corrupt(format!("implausible transaction length {len}"));
        }
        let mut tx = Vec::with_capacity(len.min(1 << 16));
        for _ in 0..len {
            tx.push(read_u32(r)?);
        }
        pending.push(tx);
    }
    Ok((epoch, minsup, pending))
}

// -- checkpoint transaction-db dump --------------------------------------

/// Persist a [`TransactionDb`] (vocab + rows) — the piece a snapshot
/// alone lacks to restore an incremental store. Used by the durability
/// plane's checkpoints (`ckpt-<id>.db`). Format, little-endian:
///
/// ```text
/// magic "TORB" | version u32 (= 1)
/// num_names u32 | per name: len u32, utf-8 bytes
/// num_tx u64 | per tx: len u32, item ids u32…
/// crc32 u32  (IEEE, over every preceding byte)
/// ```
pub fn save_db_with(vfs: &dyn Vfs, db: &TransactionDb, path: &Path) -> Result<()> {
    fsio::atomic_write_with(vfs, path, |w| {
        let mut cw = Crc32Writer::new(&mut *w);
        cw.write_all(&DB_MAGIC)?;
        cw.write_all(&DB_VERSION.to_le_bytes())?;
        let vocab = db.vocab();
        cw.write_all(&(vocab.len() as u32).to_le_bytes())?;
        for name in vocab.names() {
            cw.write_all(&(name.len() as u32).to_le_bytes())?;
            cw.write_all(name.as_bytes())?;
        }
        cw.write_all(&(db.num_transactions() as u64).to_le_bytes())?;
        for tx in db.iter() {
            cw.write_all(&(tx.len() as u32).to_le_bytes())?;
            for &it in tx {
                cw.write_all(&it.to_le_bytes())?;
            }
        }
        let crc = cw.digest();
        w.write_all(&crc.to_le_bytes())?;
        Ok(())
    })
    .with_context(|| format!("save transaction db {}", path.display()))
}

/// Load a [`save_db_with`] dump.
pub fn load_db_with(vfs: &dyn Vfs, path: &Path) -> LoadResult<TransactionDb> {
    let f = vfs.open(path).map_err(LoadError::Io)?;
    let mut r = BufReader::new(f);
    let mut head = [0u8; 8];
    r.read_exact(&mut head)?;
    if head[..4] != DB_MAGIC {
        return Err(LoadError::BadMagic);
    }
    let version = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    if version != DB_VERSION {
        return Err(LoadError::BadVersion(version));
    }
    let mut rest = Vec::new();
    r.read_to_end(&mut rest)?;
    let body = check_seal(&head, &rest)?;
    let mut br = body;
    let r = &mut br;
    let num_names = read_u32(r)? as usize;
    if num_names >= 1 << 28 {
        return corrupt(format!("implausible vocab size {num_names}"));
    }
    let mut vocab = Vocab::new();
    for i in 0..num_names {
        let len = read_u32(r)? as usize;
        if len >= 1 << 20 {
            return corrupt(format!("implausible name length {len}"));
        }
        let mut buf = vec![0u8; len];
        r.read_exact(&mut buf)?;
        match String::from_utf8(buf) {
            Ok(s) => {
                vocab.intern(&s);
            }
            Err(_) => return corrupt(format!("vocab entry {i} is not utf-8")),
        }
    }
    let num_tx = read_u64(r)? as usize;
    if num_tx >= 1 << 32 {
        return corrupt(format!("implausible transaction count {num_tx}"));
    }
    let mut builder = TransactionDb::builder(vocab);
    for _ in 0..num_tx {
        let len = read_u32(r)? as usize;
        if len >= 1 << 24 {
            return corrupt(format!("implausible transaction length {len}"));
        }
        let mut tx = Vec::with_capacity(len.min(1 << 16));
        for _ in 0..len {
            tx.push(read_u32(r)?);
        }
        builder.push_ids(tx);
    }
    if !r.is_empty() {
        return corrupt(format!("{} trailing bytes in db dump", r.len()));
    }
    Ok(builder.build())
}

// -- column I/O helpers ---------------------------------------------------

fn write_col_u32(w: &mut impl Write, col: &[u32]) -> Result<()> {
    w.write_all(&(col.len() as u32).to_le_bytes())?;
    for &v in col {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn write_col_u64(w: &mut impl Write, col: &[u64]) -> Result<()> {
    w.write_all(&(col.len() as u32).to_le_bytes())?;
    for &v in col {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn write_col_u16(w: &mut impl Write, col: &[u16]) -> Result<()> {
    w.write_all(&(col.len() as u32).to_le_bytes())?;
    for &v in col {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_col_u32<R: Read>(r: &mut R) -> LoadResult<Vec<u32>> {
    let len = read_u32(r)? as usize;
    if len >= 1 << 30 {
        return corrupt(format!("implausible column length {len}"));
    }
    let mut out = Vec::with_capacity(len.min(1 << 16));
    for _ in 0..len {
        out.push(read_u32(r)?);
    }
    Ok(out)
}

fn read_col_u64<R: Read>(r: &mut R) -> LoadResult<Vec<u64>> {
    let len = read_u32(r)? as usize;
    if len >= 1 << 30 {
        return corrupt(format!("implausible column length {len}"));
    }
    let mut out = Vec::with_capacity(len.min(1 << 16));
    for _ in 0..len {
        out.push(read_u64(r)?);
    }
    Ok(out)
}

fn read_col_u16<R: Read>(r: &mut R) -> LoadResult<Vec<u16>> {
    let len = read_u32(r)? as usize;
    if len >= 1 << 30 {
        return corrupt(format!("implausible column length {len}"));
    }
    let mut out = Vec::with_capacity(len.min(1 << 16));
    for _ in 0..len {
        let mut b = [0u8; 2];
        r.read_exact(&mut b)?;
        out.push(u16::from_le_bytes(b));
    }
    Ok(out)
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::GeneratorConfig;
    use crate::data::transaction::paper_example_db;
    use crate::mining::counts::min_count;
    use crate::mining::fpgrowth::fpgrowth;
    use crate::rules::metrics::Metric;
    use crate::trie::trie::FindOutcome;
    use crate::util::fsio::MemVfs;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("tor_ser_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{tag}.tor"))
    }

    fn build(seed: u64, minsup: f64) -> (crate::data::transaction::TransactionDb, TrieOfRules) {
        let db = GeneratorConfig::tiny(seed).generate();
        let fi = fpgrowth(&db, minsup);
        let order = ItemOrder::new(&db, min_count(minsup, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        (db, trie)
    }

    fn assert_equivalent(a: &TrieOfRules, b: &TrieOfRules) {
        assert_eq!(a.num_nodes(), b.num_nodes());
        assert_eq!(a.num_transactions(), b.num_transactions());
        assert_eq!(a.items_column(), b.items_column());
        assert_eq!(a.counts_column(), b.counts_column());
        assert_eq!(a.parents_column(), b.parents_column());
        assert_eq!(a.subtree_end_column(), b.subtree_end_column());
        assert_eq!(a.child_csr(), b.child_csr());
        assert_eq!(a.header_csr(), b.header_csr());
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let (db, trie) = build(5, 0.05);
        let path = tmpfile("roundtrip");
        save(&trie, Some(db.vocab()), &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        let vocab = vocab.expect("vocab stored");
        assert_eq!(vocab.len(), db.vocab().len());
        assert_equivalent(&trie, &back);
        // Every rule answers identically, metrics included.
        let mut checked = 0;
        trie.for_each_rule(|rule, m| {
            match back.find_rule(rule) {
                FindOutcome::Found(bm) => {
                    assert!((bm.support - m.support).abs() < 1e-15, "{rule}");
                    assert!((bm.confidence - m.confidence).abs() < 1e-15, "{rule}");
                    assert!((bm.lift - m.lift).abs() < 1e-12, "{rule}");
                }
                other => panic!("{rule}: {other:?}"),
            }
            checked += 1;
        });
        assert!(checked > 10);
        // Top-N agrees too.
        let a: Vec<f64> = trie.top_n(Metric::Lift, 5).iter().map(|&(_, v)| v).collect();
        let b: Vec<f64> = back.top_n(Metric::Lift, 5).iter().map(|&(_, v)| v).collect();
        assert_eq!(a, b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_reader_rebuilds_identical_trie() {
        let (db, trie) = build(5, 0.05);
        let path = tmpfile("v1_roundtrip");
        save_v1(&trie, Some(db.vocab()), &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        assert!(vocab.is_some());
        // The v1 path rebuilds through the builder + freeze; the preorder
        // renumbering is canonical, so the columns come back identical.
        assert_equivalent(&trie, &back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_v2_still_loads() {
        let (db, trie) = build(5, 0.05);
        let mut bytes = Vec::new();
        save_v2_to(&trie, Some(db.vocab()), &mut bytes).unwrap();
        let (back, vocab) = try_load_from(&mut &bytes[..]).unwrap();
        assert!(vocab.is_some());
        assert_equivalent(&trie, &back);
    }

    #[test]
    fn roundtrip_without_vocab() {
        let (_, trie) = build(6, 0.06);
        let path = tmpfile("novocab");
        save(&trie, None, &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        assert!(vocab.is_none());
        assert_eq!(back.num_nodes(), trie.num_nodes());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn paper_example_roundtrip() {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        let path = tmpfile("paper");
        save(&trie, Some(db.vocab()), &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        let vocab = vocab.unwrap();
        let name = |s: &str| vocab.get(s).unwrap();
        assert_eq!(back.support_of(&[name("f"), name("c")]), Some(3));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_leaves_no_temp_file_and_survives_fault() {
        let (db, trie) = build(9, 0.05);
        let vfs = MemVfs::new(11);
        let path = Path::new("snaps/a.tor");
        vfs.create_dir_all(Path::new("snaps")).unwrap();
        save_with(&vfs, &trie, Some(db.vocab()), path).unwrap();
        let good = vfs.read(path).unwrap();
        assert!(!vfs.exists(&fsio::tmp_path(path)), "temp file left behind");
        // A faulted re-save must leave the previous snapshot intact.
        vfs.fail_path_containing(Some(".tmp"));
        assert!(save_with(&vfs, &trie, Some(db.vocab()), path).is_err());
        vfs.fail_path_containing(None);
        assert_eq!(vfs.read(path).unwrap(), good);
        let (back, _) = try_load_with(&vfs, path).unwrap();
        assert_equivalent(&trie, &back);
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let path = tmpfile("garbage");
        std::fs::write(&path, b"not a trie file at all").unwrap();
        assert!(load(&path).is_err());
        assert!(matches!(try_load(&path), Err(LoadError::BadMagic)));
        // Truncated real file (all formats).
        let (db, trie) = build(7, 0.06);
        for (tag, saver) in [
            ("full_v3", save as fn(&TrieOfRules, Option<&Vocab>, &Path) -> Result<()>),
            ("full_v1", save_v1),
        ] {
            let full = tmpfile(tag);
            saver(&trie, Some(db.vocab()), &full).unwrap();
            let bytes = std::fs::read(&full).unwrap();
            std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
            assert!(load(&path).is_err(), "{tag} truncation accepted");
            std::fs::remove_file(&full).ok();
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unknown_version_is_badversion_not_corrupt() {
        let (db, trie) = build(7, 0.06);
        let mut bytes = Vec::new();
        save_v2_to(&trie, Some(db.vocab()), &mut bytes).unwrap();
        bytes[4..8].copy_from_slice(&77u32.to_le_bytes());
        match try_load_from(&mut &bytes[..]) {
            Err(LoadError::BadVersion(77)) => {}
            other => panic!("expected BadVersion(77), got {other:?}"),
        }
    }

    #[test]
    fn v1_rejects_corrupt_counts() {
        // Corrupt a node count so it exceeds its parent: loader must refuse.
        let (db, trie) = build(8, 0.06);
        let path = tmpfile("corrupt_v1");
        save_v1(&trie, Some(db.vocab()), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Last 8 bytes = last node's count; blow it up.
        let n = bytes.len();
        bytes[n - 8..].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("exceeds parent"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn delta_sidecar_roundtrip_and_rejection() {
        let path = tmpfile("sidecar");
        let pending: Vec<Vec<u32>> = vec![vec![0, 3, 5], vec![2], vec![1, 4]];
        save_delta(&path, 7, 0.005, &pending).unwrap();
        let (epoch, minsup, back) = load_delta(&path).unwrap();
        assert_eq!(epoch, 7);
        assert!((minsup - 0.005).abs() < 1e-15);
        assert_eq!(back, pending);
        // Garbage and truncation are rejected.
        std::fs::write(&path, b"not a sidecar").unwrap();
        assert!(load_delta(&path).is_err());
        save_delta(&path, 7, 0.005, &pending).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load_delta(&path).is_err());
        // A flipped payload bit fails the sidecar CRC.
        let mut flipped = bytes.clone();
        flipped[12] ^= 0x40;
        std::fs::write(&path, &flipped).unwrap();
        let err = load_delta(&path).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_rejects_tampered_columns() {
        // Flip the tail of the header-nodes column in a legacy (no-CRC)
        // v2 image: the loader re-derives the CSRs from the core columns
        // and must notice the disagreement.
        let (db, trie) = build(8, 0.06);
        let mut bytes = Vec::new();
        save_v2_to(&trie, Some(db.vocab()), &mut bytes).unwrap();
        let n = bytes.len();
        bytes[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = try_load_from(&mut &bytes[..]).unwrap_err();
        assert!(err.to_string().contains("header CSR"), "{err}");
    }

    #[test]
    fn v3_crc_catches_tampering_before_semantics() {
        let (db, trie) = build(8, 0.06);
        let mut bytes = Vec::new();
        save_to(&trie, Some(db.vocab()), &mut bytes).unwrap();
        // Flip one payload bit: rejected with a checksum error (the seal
        // is verified before any semantic validation).
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        let err = try_load_from(&mut &bytes[..]).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // Trailing garbage shifts the trailer and fails the seal too.
        bytes[mid] ^= 0x01;
        bytes.push(0);
        let err = try_load_from(&mut &bytes[..]).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn db_dump_roundtrips_and_rejects_corruption() {
        let db = paper_example_db();
        let vfs = MemVfs::new(3);
        let path = Path::new("ckpt-1.db");
        save_db_with(&vfs, &db, path).unwrap();
        let back = load_db_with(&vfs, path).unwrap();
        assert_eq!(back.num_transactions(), db.num_transactions());
        assert_eq!(back.vocab().len(), db.vocab().len());
        for (a, b) in db.iter().zip(back.iter()) {
            assert_eq!(a, b);
        }
        let len = vfs.read(path).unwrap().len();
        vfs.flip_bit(path, len / 2, 3);
        assert!(load_db_with(&vfs, path).is_err());
    }
}
