//! Trie persistence — the feature the paper's amortization argument
//! implies ("creating a ruleset is typically a one-time task"): build the
//! Trie of Rules once, save it, and serve queries from the saved structure
//! without re-mining.
//!
//! Versioned little-endian binary format:
//!
//! ```text
//! magic "TOR\x01" | version u32
//! num_transactions u64 | min_count u64
//! num_items u32 | freqs: num_items × u64
//! vocab flag u8 | if 1: num_items × (len u32, utf-8 bytes)
//! num_nodes u32 | nodes: (item u32, parent u32, count u64) in arena order
//! ```
//!
//! Only raw counts are stored; metrics, the header table and depths are
//! derived state, rebuilt (and re-validated) on load.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::data::vocab::Vocab;
use crate::mining::counts::ItemOrder;
use crate::trie::trie::TrieOfRules;

const MAGIC: [u8; 4] = *b"TOR\x01";
const VERSION: u32 = 1;

/// Save a trie (and optionally its vocabulary) to `path`.
pub fn save(trie: &TrieOfRules, vocab: Option<&Vocab>, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(trie.num_transactions() as u64).to_le_bytes())?;
    w.write_all(&trie.order().min_count_used().to_le_bytes())?;
    let freqs = trie.order().frequencies();
    w.write_all(&(freqs.len() as u32).to_le_bytes())?;
    for &f0 in freqs {
        w.write_all(&f0.to_le_bytes())?;
    }
    match vocab {
        Some(v) => {
            anyhow::ensure!(
                v.len() == freqs.len(),
                "vocab size {} != item count {}",
                v.len(),
                freqs.len()
            );
            w.write_all(&[1u8])?;
            for name in v.names() {
                w.write_all(&(name.len() as u32).to_le_bytes())?;
                w.write_all(name.as_bytes())?;
            }
        }
        None => w.write_all(&[0u8])?,
    }
    let nodes: Vec<_> = trie.raw_nodes().collect();
    w.write_all(&(nodes.len() as u32).to_le_bytes())?;
    for (item, parent, count) in nodes {
        w.write_all(&item.to_le_bytes())?;
        w.write_all(&parent.to_le_bytes())?;
        w.write_all(&count.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Load a trie (and its vocabulary, when stored) from `path`.
pub fn load(path: &Path) -> Result<(TrieOfRules, Option<Vocab>)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("read magic")?;
    anyhow::ensure!(magic == MAGIC, "not a Trie-of-Rules file (bad magic)");
    let version = read_u32(&mut r)?;
    anyhow::ensure!(version == VERSION, "unsupported version {version}");
    let num_transactions = read_u64(&mut r)? as usize;
    let min_count = read_u64(&mut r)?;
    let num_items = read_u32(&mut r)? as usize;
    anyhow::ensure!(num_items < 1 << 28, "implausible item count {num_items}");
    let mut freqs = Vec::with_capacity(num_items);
    for _ in 0..num_items {
        freqs.push(read_u64(&mut r)?);
    }
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    let vocab = if flag[0] == 1 {
        let mut v = Vocab::new();
        for i in 0..num_items {
            let len = read_u32(&mut r)? as usize;
            anyhow::ensure!(len < 1 << 20, "implausible name length {len}");
            let mut buf = vec![0u8; len];
            r.read_exact(&mut buf)?;
            let name = String::from_utf8(buf).with_context(|| format!("item {i} name"))?;
            v.intern(&name);
        }
        Some(v)
    } else {
        None
    };
    let num_nodes = read_u32(&mut r)? as usize;
    anyhow::ensure!(num_nodes < 1 << 30, "implausible node count {num_nodes}");
    let mut raw = Vec::with_capacity(num_nodes);
    for _ in 0..num_nodes {
        let item = read_u32(&mut r)?;
        let parent = read_u32(&mut r)?;
        let count = read_u64(&mut r)?;
        raw.push((item, parent, count));
    }
    let order = ItemOrder::from_frequencies(freqs, min_count);
    let trie = TrieOfRules::from_raw_nodes(order, num_transactions, &raw)?;
    Ok((trie, vocab))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::GeneratorConfig;
    use crate::data::transaction::paper_example_db;
    use crate::mining::counts::min_count;
    use crate::mining::fpgrowth::fpgrowth;
    use crate::rules::metrics::Metric;
    use crate::trie::trie::FindOutcome;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("tor_ser_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{tag}.tor"))
    }

    fn build(seed: u64, minsup: f64) -> (crate::data::transaction::TransactionDb, TrieOfRules) {
        let db = GeneratorConfig::tiny(seed).generate();
        let fi = fpgrowth(&db, minsup);
        let order = ItemOrder::new(&db, min_count(minsup, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        (db, trie)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let (db, trie) = build(5, 0.05);
        let path = tmpfile("roundtrip");
        save(&trie, Some(db.vocab()), &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        let vocab = vocab.expect("vocab stored");
        assert_eq!(vocab.len(), db.vocab().len());
        assert_eq!(back.num_nodes(), trie.num_nodes());
        assert_eq!(back.num_transactions(), trie.num_transactions());
        // Every rule answers identically, metrics included.
        let mut checked = 0;
        trie.for_each_rule(|rule, m| {
            match back.find_rule(rule) {
                FindOutcome::Found(bm) => {
                    assert!((bm.support - m.support).abs() < 1e-15, "{rule}");
                    assert!((bm.confidence - m.confidence).abs() < 1e-15, "{rule}");
                    assert!((bm.lift - m.lift).abs() < 1e-12, "{rule}");
                }
                other => panic!("{rule}: {other:?}"),
            }
            checked += 1;
        });
        assert!(checked > 10);
        // Top-N agrees too.
        let a: Vec<f64> = trie.top_n(Metric::Lift, 5).iter().map(|&(_, v)| v).collect();
        let b: Vec<f64> = back.top_n(Metric::Lift, 5).iter().map(|&(_, v)| v).collect();
        assert_eq!(a, b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_without_vocab() {
        let (_, trie) = build(6, 0.06);
        let path = tmpfile("novocab");
        save(&trie, None, &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        assert!(vocab.is_none());
        assert_eq!(back.num_nodes(), trie.num_nodes());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn paper_example_roundtrip() {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        let path = tmpfile("paper");
        save(&trie, Some(db.vocab()), &path).unwrap();
        let (back, vocab) = load(&path).unwrap();
        let vocab = vocab.unwrap();
        let name = |s: &str| vocab.get(s).unwrap();
        assert_eq!(back.support_of(&[name("f"), name("c")]), Some(3));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let path = tmpfile("garbage");
        std::fs::write(&path, b"not a trie file at all").unwrap();
        assert!(load(&path).is_err());
        // Truncated real file.
        let (db, trie) = build(7, 0.06);
        let full = tmpfile("full");
        save(&trie, Some(db.vocab()), &full).unwrap();
        let bytes = std::fs::read(&full).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&full).ok();
    }

    #[test]
    fn rejects_corrupt_counts() {
        // Corrupt a node count so it exceeds its parent: loader must refuse.
        let (db, trie) = build(8, 0.06);
        let path = tmpfile("corrupt");
        save(&trie, Some(db.vocab()), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Last 8 bytes = last node's count; blow it up.
        let n = bytes.len();
        bytes[n - 8..].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("exceeds parent"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
