//! Mutable construction of the Trie of Rules.
//!
//! [`TrieBuilder`] owns the paper's Step-3 machinery — inserting
//! frequency-ordered paths into an arena of [`TrieNode`]s with per-node
//! child vectors — and nothing else. Serving happens on the immutable,
//! preorder-renumbered, columnar [`TrieOfRules`] produced by
//! [`TrieBuilder::freeze`].
//!
//! `freeze()` always materializes the **owned** `ColumnStore` backend
//! (`trie::store::OwnedColumns`); the `mmap`-served v4 backend only ever
//! comes from `serialize::open` on a written snapshot. Both sit behind the
//! same accessor surface, so everything downstream of freeze is
//! backend-oblivious.
//!
//! The builder intentionally keeps the *old* pointer-shaped read paths
//! (child-vector `walk`, stack-DFS traversal, on-demand metric
//! computation): they are the reference oracle for the freeze parity
//! property tests (`rust/tests/freeze.rs`) and the "old layout" arm of
//! `benches/ablation_trie.rs`. Hot serving paths must not call them.

use std::collections::HashSet;

use anyhow::{bail, Context, Result};

use crate::data::vocab::ItemId;
use crate::mining::apriori::SupportCounter;
use crate::mining::counts::ItemOrder;
use crate::mining::itemset::{FrequentItemsets, Itemset};
use crate::rules::metrics::{Metric, RuleCounts, RuleMetrics};
use crate::rules::rule::Rule;
use crate::trie::node::{NodeIdx, TrieNode, ROOT, ROOT_ITEM};
use crate::trie::trie::{FindOutcome, TrieOfRules};

/// The mutable Trie-of-Rules under construction.
///
/// No header table lives here: the frozen form derives its CSR header
/// (item-rank → preorder node list) at freeze time, so there is no
/// `HashMap` anywhere on a serving path and two builds of the same input
/// are bit-identical.
#[derive(Debug, Clone)]
pub struct TrieBuilder {
    nodes: Vec<TrieNode>,
    order: ItemOrder,
    num_transactions: usize,
}

impl TrieBuilder {
    // ------------------------------------------------------------------
    // construction
    // ------------------------------------------------------------------

    fn empty(order: ItemOrder, num_transactions: usize) -> Self {
        let root = TrieNode {
            item: ROOT_ITEM,
            count: num_transactions as u64,
            parent: ROOT,
            depth: 0,
            children: Vec::new(),
        };
        Self {
            nodes: vec![root],
            order,
            num_transactions,
        }
    }

    /// Build from a *complete* frequent-itemset collection (e.g. Apriori or
    /// FP-growth output — the paper's evaluation setting). Every path
    /// prefix of a frequency-ordered frequent itemset is itself frequent,
    /// so all node supports come from the mining output with no recounting.
    pub fn from_frequent(fi: &FrequentItemsets, order: &ItemOrder) -> Result<TrieBuilder> {
        let support: std::collections::HashMap<&Itemset, u64> =
            fi.sets.iter().map(|(s, c)| (s, *c)).collect();
        let mut trie = Self::empty(order.clone(), fi.num_transactions);
        for (set, _) in &fi.sets {
            let path = order.order_itemset(set.items());
            trie.insert_path(&path, |prefix| {
                let key = Itemset::new(prefix.to_vec());
                support.get(&key).copied().with_context(|| {
                    format!("prefix {key} missing from frequent set (downward closure violated)")
                })
            })?;
        }
        Ok(trie)
    }

    /// Build from frequent *sequences* (the paper's Step 1: FP-max output)
    /// plus a support-counting backend for the prefix supports the maximal
    /// sets don't carry. The backend may be the rust bitset counter or the
    /// XLA-artifact counter — this is the trie-side integration point of
    /// the L1 Pallas kernel.
    pub fn from_sequences(
        sequences: &[(Vec<ItemId>, u64)],
        order: &ItemOrder,
        counter: &mut dyn SupportCounter,
        num_transactions: usize,
    ) -> Result<TrieBuilder> {
        // Gather every distinct proper prefix that needs a support count.
        // Dedup hashes borrowed slices into `sequences` — the only
        // allocation per distinct prefix is the one `Itemset` pushed to
        // `need`, and first-insertion order keeps the counting batch
        // deterministic.
        let mut need: Vec<Itemset> = Vec::new();
        let mut seen: HashSet<&[ItemId]> = HashSet::new();
        for (seq, _) in sequences {
            for d in 1..seq.len() {
                let prefix = &seq[..d];
                if seen.insert(prefix) {
                    need.push(Itemset::new(prefix.to_vec()));
                }
            }
        }
        let counts = counter.count(&need);
        let mut support: std::collections::HashMap<Itemset, u64> =
            need.into_iter().zip(counts).collect();
        // Full sequences carry known counts; they override any prefix
        // count (a maximal sequence may be a proper prefix of another).
        for (seq, count) in sequences {
            support.insert(Itemset::new(seq.clone()), *count);
        }

        let mut trie = Self::empty(order.clone(), num_transactions);
        for (seq, _) in sequences {
            let path = order.order_itemset(seq);
            trie.insert_path(&path, |prefix| {
                let key = Itemset::new(prefix.to_vec());
                support
                    .get(&key)
                    .copied()
                    .with_context(|| format!("prefix {key} not counted"))
            })?;
        }
        Ok(trie)
    }

    /// Insert one frequency-ordered path, annotating every newly created
    /// node with its true support from `support_of` (paper Step 3).
    pub fn insert_path(
        &mut self,
        path: &[ItemId],
        mut support_of: impl FnMut(&[ItemId]) -> Result<u64>,
    ) -> Result<()> {
        if path.is_empty() {
            bail!("cannot insert an empty path");
        }
        let mut cur = ROOT;
        for depth in 1..=path.len() {
            let item = path[depth - 1];
            cur = match self.nodes[cur as usize].child(item) {
                Some(c) => c,
                None => {
                    let count = support_of(&path[..depth])?;
                    let idx = self.nodes.len() as NodeIdx;
                    self.nodes.push(TrieNode {
                        item,
                        count,
                        parent: cur,
                        depth: depth as u16,
                        children: Vec::new(),
                    });
                    self.nodes[cur as usize].link_child(item, idx);
                    idx
                }
            };
        }
        Ok(())
    }

    /// Rebuild a builder from raw node triples `(item, parent, count)` in
    /// parent-before-child order (the serializer's v1 wire form; see
    /// [`TrieOfRules::raw_nodes`]).
    pub fn from_raw_nodes(
        order: ItemOrder,
        num_transactions: usize,
        raw: &[(ItemId, NodeIdx, u64)],
    ) -> Result<TrieBuilder> {
        let mut trie = Self::empty(order, num_transactions);
        for &(item, parent, count) in raw {
            let idx = trie.nodes.len() as NodeIdx;
            anyhow::ensure!(
                (parent as usize) < trie.nodes.len(),
                "node {idx}: parent {parent} not yet defined (corrupt file?)"
            );
            anyhow::ensure!(
                (item as usize) < trie.order.frequencies().len(),
                "node {idx}: item {item} out of range ({} items)",
                trie.order.frequencies().len()
            );
            anyhow::ensure!(
                trie.order.is_frequent(item),
                "node {idx}: item {item} is not frequent under the stored order"
            );
            let parent_node = &trie.nodes[parent as usize];
            let c_a = parent_node.count;
            anyhow::ensure!(
                count <= c_a,
                "node {idx}: count {count} exceeds parent count {c_a}"
            );
            let depth = parent_node.depth + 1;
            trie.nodes.push(TrieNode {
                item,
                count,
                parent,
                depth,
                children: Vec::new(),
            });
            anyhow::ensure!(
                trie.nodes[parent as usize].link_child(item, idx),
                "node {idx}: duplicate child {item} under {parent}"
            );
        }
        Ok(trie)
    }

    // ------------------------------------------------------------------
    // freeze — the handoff to the serving layout
    // ------------------------------------------------------------------

    /// Produce the immutable, DFS-preorder-renumbered, columnar serving
    /// form. Children are visited in child-vector (item-id) order, so the
    /// renumbering — and every downstream column — is deterministic.
    ///
    /// Preorder numbering is what turns subtrees into contiguous index
    /// ranges `[i, subtree_end[i])`: support pruning becomes a range skip
    /// and full traversal a linear array sweep (see `TrieOfRules`).
    pub fn freeze(&self) -> TrieOfRules {
        let n = self.nodes.len();
        let mut items = Vec::with_capacity(n);
        let mut counts = Vec::with_capacity(n);
        let mut parents = Vec::with_capacity(n);
        let mut depths = Vec::with_capacity(n);
        // old index -> new (preorder) index
        let mut renum = vec![0 as NodeIdx; n];
        // Explicit preorder DFS; children pushed in reverse child-vector
        // order so the smallest item pops (and numbers) first.
        let mut stack: Vec<NodeIdx> = vec![ROOT];
        while let Some(old) = stack.pop() {
            let node = &self.nodes[old as usize];
            let new = items.len() as NodeIdx;
            renum[old as usize] = new;
            items.push(node.item);
            counts.push(node.count);
            // Parents always precede children in preorder, so the parent's
            // new index is already final.
            parents.push(if old == ROOT { ROOT } else { renum[node.parent as usize] });
            depths.push(node.depth);
            for &(_, child) in node.children.iter().rev() {
                stack.push(child);
            }
        }
        debug_assert_eq!(items.len(), n, "freeze visited every node exactly once");
        TrieOfRules::from_core_columns(
            self.order.clone(),
            self.num_transactions,
            items,
            counts,
            parents,
            depths,
        )
        .expect("builder invariants guarantee valid columns")
    }

    // ------------------------------------------------------------------
    // accessors (tests, oracle, ablation)
    // ------------------------------------------------------------------

    pub fn num_transactions(&self) -> usize {
        self.num_transactions
    }

    /// Number of nodes excluding the root.
    pub fn num_nodes(&self) -> usize {
        self.nodes.len() - 1
    }

    pub fn order(&self) -> &ItemOrder {
        &self.order
    }

    pub fn node(&self, idx: NodeIdx) -> &TrieNode {
        &self.nodes[idx as usize]
    }

    /// Items on the path root→`idx`, root-first.
    pub fn path_items(&self, idx: NodeIdx) -> Vec<ItemId> {
        let mut rev = Vec::new();
        let mut cur = idx;
        while cur != ROOT {
            rev.push(self.nodes[cur as usize].item);
            cur = self.nodes[cur as usize].parent;
        }
        rev.reverse();
        rev
    }

    /// Walk the ordered path for `items`, returning the final node.
    pub fn walk(&self, ordered_path: &[ItemId]) -> Option<NodeIdx> {
        let mut cur = ROOT;
        for &item in ordered_path {
            cur = self.nodes[cur as usize].child(item)?;
        }
        Some(cur)
    }

    /// Absolute support count of an itemset, if its ordered path exists.
    pub fn support_of(&self, items: &[ItemId]) -> Option<u64> {
        if items.iter().any(|&i| !self.order.is_frequent(i)) {
            return None;
        }
        let path = self.order.order_itemset(items);
        self.walk(&path).map(|n| self.nodes[n as usize].count)
    }

    /// Metric vector of the stored node-rule at `idx`, computed on demand
    /// from counts (the builder stores no metrics).
    fn node_metrics(&self, idx: NodeIdx) -> RuleMetrics {
        let node = &self.nodes[idx as usize];
        RuleMetrics::from_counts(RuleCounts {
            n: (self.num_transactions as u64).max(1),
            c_ac: node.count,
            c_a: self.nodes[node.parent as usize].count,
            c_c: if node.item == ROOT_ITEM {
                node.count
            } else {
                self.order.frequency(node.item)
            },
        })
    }

    // ------------------------------------------------------------------
    // oracle read paths (pointer-shaped "old layout")
    // ------------------------------------------------------------------

    /// Pointer-walk rule lookup — semantically identical to the frozen
    /// [`TrieOfRules::find_rule`]; kept as the parity oracle and the
    /// old-layout arm of the ablation bench.
    pub fn find_rule(&self, rule: &Rule) -> FindOutcome {
        let a = rule.antecedent.items();
        let c = rule.consequent.items();
        if a.iter().chain(c).any(|&i| !self.order.is_frequent(i)) {
            return FindOutcome::Absent;
        }
        let max_a = a.iter().map(|&i| self.order.rank(i).unwrap()).max().unwrap();
        let min_c = c.iter().map(|&i| self.order.rank(i).unwrap()).min().unwrap();
        if max_a >= min_c {
            return FindOutcome::NotRepresentable;
        }
        let a_path = self.order.order_itemset(a);
        let c_path = self.order.order_itemset(c);
        let Some(a_node) = self.walk(&a_path) else {
            return FindOutcome::Absent;
        };
        let mut cur = a_node;
        for &item in &c_path {
            match self.nodes[cur as usize].child(item) {
                Some(nxt) => cur = nxt,
                None => return FindOutcome::Absent,
            }
        }
        if c_path.len() == 1 {
            return FindOutcome::Found(self.node_metrics(cur));
        }
        let c_ac = self.nodes[cur as usize].count;
        let c_a = self.nodes[a_node as usize].count;
        let c_c = match self.walk(&c_path) {
            Some(c_node) => self.nodes[c_node as usize].count,
            None => self.num_transactions as u64,
        };
        FindOutcome::Found(RuleMetrics::from_counts(RuleCounts {
            n: self.num_transactions as u64,
            c_ac,
            c_a,
            c_c,
        }))
    }

    /// Stack-DFS split traversal with support pruning — the old-layout
    /// twin of [`TrieOfRules::for_each_rule_pruned`], same emission
    /// semantics (per-node visit order differs; callers must not depend on
    /// it). Returns nodes visited (pruned nodes included, their
    /// descendants not).
    pub fn for_each_rule_pruned(
        &self,
        mut prune: impl FnMut(f64) -> bool,
        mut f: impl FnMut(&[ItemId], &[ItemId], &RuleMetrics),
    ) -> usize {
        let n = self.num_transactions as u64;
        let n_f = self.num_transactions as f64;
        let mut visited = 0usize;
        let mut stack: Vec<(NodeIdx, usize)> = self.nodes[ROOT as usize]
            .children
            .iter()
            .map(|&(_, c)| (c, 1usize))
            .collect();
        let mut items: Vec<ItemId> = Vec::new();
        let mut counts: Vec<u64> = Vec::new();
        while let Some((idx, depth)) = stack.pop() {
            items.truncate(depth - 1);
            counts.truncate(depth - 1);
            let node = &self.nodes[idx as usize];
            visited += 1;
            items.push(node.item);
            counts.push(node.count);
            if prune(node.count as f64 / n_f) {
                continue;
            }
            for split in 1..items.len() {
                let consequent = &items[split..];
                let c_c = if consequent.len() == 1 {
                    self.order.frequency(consequent[0])
                } else {
                    match self.support_of(consequent) {
                        Some(c) => c,
                        None => n,
                    }
                };
                let metrics = RuleMetrics::from_counts(RuleCounts {
                    n,
                    c_ac: node.count,
                    c_a: counts[split - 1],
                    c_c,
                });
                f(&items[..split], consequent, &metrics);
            }
            for &(_, child) in &node.children {
                stack.push((child, depth + 1));
            }
        }
        visited
    }

    /// Stack-DFS support/confidence traversal (old-layout ablation arm).
    pub fn for_each_split(&self, mut f: impl FnMut(&[ItemId], &[ItemId], f64, f64)) {
        let n = self.num_transactions as f64;
        let mut stack: Vec<(NodeIdx, usize)> = self.nodes[ROOT as usize]
            .children
            .iter()
            .map(|&(_, c)| (c, 1usize))
            .collect();
        let mut items: Vec<ItemId> = Vec::new();
        let mut counts: Vec<u64> = Vec::new();
        while let Some((idx, depth)) = stack.pop() {
            items.truncate(depth - 1);
            counts.truncate(depth - 1);
            let node = &self.nodes[idx as usize];
            items.push(node.item);
            counts.push(node.count);
            let support = node.count as f64 / n;
            for split in 1..items.len() {
                let confidence = node.count as f64 / counts[split - 1] as f64;
                f(&items[..split], &items[split..], support, confidence);
            }
            for &(_, child) in &node.children {
                stack.push((child, depth + 1));
            }
        }
    }

    /// Top-`k` stored node-rules by `metric`, descending — oracle for the
    /// frozen column-scan [`TrieOfRules::top_n`]. Ranks by value only (ties
    /// may order differently across layouts).
    pub fn top_n(&self, metric: Metric, k: usize) -> Vec<(NodeIdx, f64)> {
        let mut all: Vec<(f64, NodeIdx)> = (1..self.nodes.len())
            .filter(|&i| self.nodes[i].depth >= 2)
            .map(|i| (self.node_metrics(i as NodeIdx).get(metric), i as NodeIdx))
            .collect();
        all.sort_by(|a, b| b.0.total_cmp(&a.0));
        all.truncate(k);
        all.into_iter().map(|(v, i)| (i, v)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::paper_example_db;
    use crate::mining::counts::min_count;
    use crate::mining::fpgrowth::fpgrowth;

    fn paper_builder() -> (crate::data::transaction::TransactionDb, TrieBuilder) {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let b = TrieBuilder::from_frequent(&fi, &order).unwrap();
        (db, b)
    }

    #[test]
    fn builder_counts_are_true_supports() {
        let (db, b) = paper_builder();
        for idx in 1..=b.num_nodes() {
            let items = b.path_items(idx as NodeIdx);
            let truth = db
                .iter()
                .filter(|tx| items.iter().all(|i| tx.contains(i)))
                .count() as u64;
            assert_eq!(b.node(idx as NodeIdx).count, truth, "path {items:?}");
        }
    }

    #[test]
    fn freeze_preserves_node_population() {
        let (db, b) = paper_builder();
        let frozen = b.freeze();
        assert_eq!(frozen.num_nodes(), b.num_nodes());
        assert_eq!(frozen.num_transactions(), b.num_transactions());
        // Every builder path exists in the frozen trie with the same count.
        for idx in 1..=b.num_nodes() {
            let items = b.path_items(idx as NodeIdx);
            let f = frozen.walk(&items).expect("path lost in freeze");
            assert_eq!(frozen.count(f), b.node(idx as NodeIdx).count, "path {items:?}");
        }
        let name = |s: &str| db.vocab().get(s).unwrap();
        assert_eq!(frozen.support_of(&[name("f"), name("c")]), Some(3));
    }

    #[test]
    fn builder_find_rule_matches_frozen() {
        let (_, b) = paper_builder();
        let frozen = b.freeze();
        frozen.for_each_rule(|rule, m| {
            match b.find_rule(rule) {
                FindOutcome::Found(bm) => {
                    assert!((bm.confidence - m.confidence).abs() < 1e-12, "{rule}");
                    assert!((bm.support - m.support).abs() < 1e-12, "{rule}");
                }
                other => panic!("builder lost {rule}: {other:?}"),
            }
        });
    }
}
