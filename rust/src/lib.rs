//! # Trie of Rules
//!
//! A production-grade reproduction of *"Exploring the Trie of Rules: a fast
//! data structure for the representation of association rules"*
//! (Kudriavtsev, Bezbradica, McCarren; 2023), built as a three-layer
//! rust + JAX + Pallas data pipeline:
//!
//! * **L3 (this crate)** — the full association-rule-mining pipeline and the
//!   paper's contribution: streaming ingestion, sharded mining with
//!   backpressure, rule generation, the [`trie::TrieOfRules`] structure, the
//!   pandas-semantics [`baseline::RuleFrame`], the RQL rule-query engine
//!   ([`query`]: parser → trie-aware planner → streaming executor), and the
//!   query service that fronts it.
//! * **L2/L1 (python/, build-time only)** — JAX graphs + Pallas kernels for
//!   the tensor-shaped mining hot-spot (batched itemset-support counting and
//!   vectorized rule metrics), AOT-lowered to HLO text and executed from
//!   rust via PJRT ([`runtime`]).
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every figure of the paper to a bench target.

pub mod baseline;
pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod mining;
pub mod obs;
pub mod query;
pub mod rules;
pub mod runtime;
pub mod stats;
pub mod trie;
pub mod util;
