//! LEB128 variable-length integers — the v4 snapshot preamble codec
//! (DESIGN.md §17).
//!
//! Encoding: 7 value bits per byte, least-significant group first, high
//! bit set on every byte except the last. `u64::MAX` takes 10 bytes; the
//! encoder always emits the canonical (shortest) form, so identical
//! values produce identical bytes — a requirement of the byte-determinism
//! contract every snapshot writer obeys.
//!
//! Decoding is hardened for untrusted input: truncation and non-
//! terminating sequences return a typed [`VarintError`] (mapped to
//! `LoadError::Corrupt` by the serializer) and never panic.

/// Why a varint failed to decode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VarintError {
    /// The buffer ended before the terminating byte.
    Truncated,
    /// More than 10 bytes, or bits beyond the 64th — not a `u64`.
    Overflow,
}

impl std::fmt::Display for VarintError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VarintError::Truncated => write!(f, "varint truncated"),
            VarintError::Overflow => write!(f, "varint overflows u64"),
        }
    }
}

impl std::error::Error for VarintError {}

/// Number of bytes [`encode_u64`] will append for `v`.
pub fn encoded_len(v: u64) -> usize {
    let bits = 64 - v.leading_zeros() as usize;
    bits.div_ceil(7).max(1)
}

/// Append the canonical LEB128 encoding of `v` to `out`.
pub fn encode_u64(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Decode one varint from `buf` starting at `*pos`, advancing `*pos` past
/// it. Never panics: truncated or overlong input reports a typed error.
pub fn decode_u64(buf: &[u8], pos: &mut usize) -> Result<u64, VarintError> {
    let mut value: u64 = 0;
    let mut shift: u32 = 0;
    loop {
        let Some(&byte) = buf.get(*pos) else {
            return Err(VarintError::Truncated);
        };
        *pos += 1;
        let group = u64::from(byte & 0x7f);
        if shift == 63 && group > 1 {
            // 10th byte may only carry the single remaining bit.
            return Err(VarintError::Overflow);
        }
        value |= group << shift;
        if byte & 0x80 == 0 {
            return Ok(value);
        }
        shift += 7;
        if shift > 63 {
            return Err(VarintError::Overflow);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Boundary values around every 7-bit group edge plus extremes.
    fn boundary_values() -> Vec<u64> {
        let mut vals = vec![0u64, 1, 2, u64::MAX, u64::MAX - 1];
        for k in 1..10u32 {
            let edge = 1u64 << (7 * k);
            vals.extend([edge - 1, edge, edge + 1]);
        }
        vals.push(1u64 << 63);
        vals
    }

    #[test]
    fn roundtrip_boundary_values() {
        for v in boundary_values() {
            let mut buf = Vec::new();
            encode_u64(&mut buf, v);
            assert_eq!(buf.len(), encoded_len(v), "length for {v}");
            assert!(buf.len() <= 10);
            let mut pos = 0;
            assert_eq!(decode_u64(&buf, &mut pos), Ok(v));
            assert_eq!(pos, buf.len(), "decoder must consume exactly {v}");
        }
    }

    #[test]
    fn roundtrip_fuzz() {
        let mut rng = Rng::new(0x7a71);
        let mut buf = Vec::new();
        for _ in 0..20_000 {
            // Mix uniform values with small ones (the common columns).
            let v = match rng.below(3) {
                0 => rng.next_u64(),
                1 => rng.next_u64() & 0xffff,
                _ => rng.next_u64() >> (rng.below(64) as u32),
            };
            buf.clear();
            encode_u64(&mut buf, v);
            let mut pos = 0;
            assert_eq!(decode_u64(&buf, &mut pos), Ok(v));
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn concatenated_stream_decodes_in_order() {
        let vals = boundary_values();
        let mut buf = Vec::new();
        for &v in &vals {
            encode_u64(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(decode_u64(&buf, &mut pos), Ok(v));
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn truncation_is_typed_never_panics() {
        for v in boundary_values() {
            let mut buf = Vec::new();
            encode_u64(&mut buf, v);
            for cut in 0..buf.len() {
                let mut pos = 0;
                match decode_u64(&buf[..cut], &mut pos) {
                    Err(VarintError::Truncated) => {}
                    // A prefix of a multi-byte encoding can end on a byte
                    // without the continuation bit only if it is complete.
                    Ok(_) if cut == buf.len() => {}
                    other => panic!("cut {cut} of {v}: {other:?}"),
                }
            }
        }
        // Empty input.
        let mut pos = 0;
        assert_eq!(decode_u64(&[], &mut pos), Err(VarintError::Truncated));
    }

    #[test]
    fn overlong_and_overflowing_input_rejected() {
        // 11 continuation bytes: overflow, not a hang.
        let mut pos = 0;
        assert_eq!(
            decode_u64(&[0x80u8; 11], &mut pos),
            Err(VarintError::Overflow)
        );
        // 10th byte carrying more than the last bit of a u64.
        let mut buf = vec![0xffu8; 9];
        buf.push(0x02);
        let mut pos = 0;
        assert_eq!(decode_u64(&buf, &mut pos), Err(VarintError::Overflow));
        // u64::MAX itself is fine (10th byte = 0x01).
        let mut buf = Vec::new();
        encode_u64(&mut buf, u64::MAX);
        assert_eq!(buf.len(), 10);
        assert_eq!(*buf.last().unwrap(), 0x01);
        let mut pos = 0;
        assert_eq!(decode_u64(&buf, &mut pos), Ok(u64::MAX));
    }

    #[test]
    fn garbage_fuzz_never_panics() {
        let mut rng = Rng::new(0xbad5eed);
        for _ in 0..5_000 {
            let len = rng.below(16);
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let mut pos = 0;
            // Any outcome is fine; the property is "no panic, pos advances
            // at most to the end".
            let _ = decode_u64(&bytes, &mut pos);
            assert!(pos <= bytes.len());
        }
    }
}
