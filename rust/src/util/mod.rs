//! Foundation utilities: deterministic RNG, bitsets, JSON, timing, and the
//! in-house property-testing harness (offline builds vendor only the `xla`
//! crate's closure — see DESIGN.md §3).

pub mod bitpack;
pub mod bitset;
pub mod crc32;
pub mod fsio;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod timer;
pub mod varint;
