//! Fixed-width bit packing — the v4 snapshot column codec (DESIGN.md §17).
//!
//! A column of `count` unsigned values is stored at the minimal width
//! `w = bits_for(max)` bits per value, LSB-first: value `i` occupies bits
//! `[i*w, (i+1)*w)` of the little-endian byte stream. Widths are capped at
//! 56 so every value can be read with a single unaligned 8-byte
//! little-endian window (`shift + width <= 63`); columns whose maximum
//! needs more than 56 bits fall back to the raw `u64` codec. The payload
//! carries 8 trailing guard zero bytes so the 8-byte window read is always
//! in bounds without per-access branching.
//!
//! Like the varint codec, reads are hardened for untrusted input:
//! [`PackedSlice::new`] validates the payload length up front and returns
//! a typed error; after that, `get` is branch-light and panic-free.

/// Hard cap on packed width: keeps `shift + width <= 63` for the
/// single-window read in [`PackedSlice::get`].
pub const MAX_PACKED_WIDTH: u8 = 56;

/// Guard bytes appended after the packed bits so an 8-byte window read at
/// the last value never runs past the buffer.
pub const GUARD_BYTES: usize = 8;

/// Minimal width able to represent `max` (0 for `max == 0`, up to 64).
pub fn bits_for(max: u64) -> u8 {
    (64 - max.leading_zeros()) as u8
}

/// Packed payload length in bytes for `count` values at `width` bits,
/// including the guard. Zero-width and empty columns have no payload.
pub fn payload_len(count: usize, width: u8) -> usize {
    if count == 0 || width == 0 {
        return 0;
    }
    let bits = count * width as usize;
    bits.div_ceil(8) + GUARD_BYTES
}

/// Pack `values` at `width` bits each, LSB-first into little-endian bytes,
/// followed by [`GUARD_BYTES`] zeros. Every value must fit in `width`
/// bits and `width` must be `<= MAX_PACKED_WIDTH` (writer-side invariants;
/// the writer chooses `width = bits_for(max)`).
pub fn pack(values: &[u64], width: u8) -> Vec<u8> {
    assert!(width <= MAX_PACKED_WIDTH, "packed width {width} > 56");
    let len = payload_len(values.len(), width);
    if len == 0 {
        return Vec::new();
    }
    let mask = (1u64 << width) - 1;
    let mut out = vec![0u8; len];
    for (i, &v) in values.iter().enumerate() {
        debug_assert!(v <= mask, "value {v} exceeds width {width}");
        let bit = i * width as usize;
        let byte = bit / 8;
        let shift = (bit % 8) as u32;
        // Read-modify-write an 8-byte little-endian window; the guard
        // guarantees `byte + 8 <= len`.
        let mut window = u64::from_le_bytes(out[byte..byte + 8].try_into().unwrap());
        window |= (v & mask) << shift;
        out[byte..byte + 8].copy_from_slice(&window.to_le_bytes());
    }
    out
}

/// Read value `i` from a packed payload whose length was already
/// validated against `payload_len(count, width)` — the guard keeps the
/// 8-byte window in bounds for every `i < count`. The single authoritative
/// decode; [`PackedSlice::get`] and the mmap section views delegate here.
#[inline(always)]
pub fn get(data: &[u8], width: u8, i: usize) -> u64 {
    if width == 0 {
        return 0;
    }
    let bit = i * width as usize;
    let byte = bit / 8;
    let shift = (bit % 8) as u32;
    let window = u64::from_le_bytes(data[byte..byte + 8].try_into().unwrap());
    (window >> shift) & ((1u64 << width) - 1)
}

/// Why a packed payload failed validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BitpackError {
    /// Width byte outside `0..=56`.
    BadWidth(u8),
    /// Payload length does not match `payload_len(count, width)`.
    BadLength { expected: usize, got: usize },
}

impl std::fmt::Display for BitpackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BitpackError::BadWidth(w) => write!(f, "bit-packed width {w} out of range 0..=56"),
            BitpackError::BadLength { expected, got } => {
                write!(f, "bit-packed payload length {got}, expected {expected}")
            }
        }
    }
}

impl std::error::Error for BitpackError {}

/// A validated view over a packed payload. Construction checks the length
/// invariant once; `get` then reads without bounds branches.
#[derive(Clone, Copy)]
pub struct PackedSlice<'a> {
    data: &'a [u8],
    width: u8,
    count: usize,
}

impl<'a> PackedSlice<'a> {
    /// Validate `data` as a packed payload of `count` values at `width`
    /// bits. Truncated or oversized payloads are a typed error, never a
    /// panic.
    pub fn new(data: &'a [u8], count: usize, width: u8) -> Result<Self, BitpackError> {
        if width > MAX_PACKED_WIDTH {
            return Err(BitpackError::BadWidth(width));
        }
        let expected = payload_len(count, width);
        if data.len() != expected {
            return Err(BitpackError::BadLength {
                expected,
                got: data.len(),
            });
        }
        Ok(PackedSlice { data, width, count })
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Read value `i`. Zero-width columns are all zeros by definition.
    #[inline(always)]
    pub fn get(&self, i: usize) -> u64 {
        debug_assert!(i < self.count, "packed index {i} out of {}", self.count);
        get(self.data, self.width, i)
    }

    /// Materialize the column (cold path: lazy slice caches, validation).
    pub fn to_vec(&self) -> Vec<u64> {
        (0..self.count).map(|i| self.get(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bits_for_boundaries() {
        assert_eq!(bits_for(0), 0);
        assert_eq!(bits_for(1), 1);
        assert_eq!(bits_for(2), 2);
        assert_eq!(bits_for(255), 8);
        assert_eq!(bits_for(256), 9);
        assert_eq!(bits_for((1 << 56) - 1), 56);
        assert_eq!(bits_for(1 << 56), 57);
        assert_eq!(bits_for(u64::MAX), 64);
    }

    #[test]
    fn payload_len_formula() {
        assert_eq!(payload_len(0, 13), 0);
        assert_eq!(payload_len(7, 0), 0);
        assert_eq!(payload_len(1, 1), 1 + GUARD_BYTES);
        assert_eq!(payload_len(8, 1), 1 + GUARD_BYTES);
        assert_eq!(payload_len(9, 1), 2 + GUARD_BYTES);
        assert_eq!(payload_len(3, 56), 21 + GUARD_BYTES);
    }

    #[test]
    fn roundtrip_every_width() {
        let mut rng = Rng::new(0xb17);
        for width in 0..=MAX_PACKED_WIDTH {
            let mask = if width == 0 { 0 } else { (1u64 << width) - 1 };
            for count in [0usize, 1, 2, 7, 8, 9, 63, 64, 65, 200] {
                let values: Vec<u64> = (0..count)
                    .map(|i| match i % 4 {
                        0 => 0,
                        1 => mask,
                        2 => rng.next_u64() & mask,
                        _ => (i as u64) & mask,
                    })
                    .collect();
                let packed = pack(&values, width);
                assert_eq!(packed.len(), payload_len(count, width));
                let slice = PackedSlice::new(&packed, count, width).unwrap();
                for (i, &v) in values.iter().enumerate() {
                    assert_eq!(slice.get(i), v, "width {width} count {count} idx {i}");
                }
                assert_eq!(slice.to_vec(), values);
            }
        }
    }

    #[test]
    fn guard_bytes_are_zero_and_deterministic() {
        let values = [5u64, 3, 7, 1];
        let a = pack(&values, 3);
        let b = pack(&values, 3);
        assert_eq!(a, b);
        assert_eq!(&a[a.len() - GUARD_BYTES..], &[0u8; GUARD_BYTES]);
    }

    #[test]
    fn lsb_first_layout_pinned() {
        // Three 3-bit values 0b001, 0b010, 0b011 → bits 011 010 001 LSB
        // first → first byte 0b11010001 = 0xd1, second byte 0.
        let packed = pack(&[1, 2, 3], 3);
        assert_eq!(packed[0], 0xd1);
        assert_eq!(packed[1], 0x00);
    }

    #[test]
    fn truncated_or_padded_payload_is_typed_error() {
        let values: Vec<u64> = (0..50).map(|i| i * 3).collect();
        let packed = pack(&values, 8);
        for cut in 0..packed.len() {
            assert!(matches!(
                PackedSlice::new(&packed[..cut], values.len(), 8),
                Err(BitpackError::BadLength { .. })
            ));
        }
        let mut padded = packed.clone();
        padded.push(0);
        assert!(matches!(
            PackedSlice::new(&padded, values.len(), 8),
            Err(BitpackError::BadLength { .. })
        ));
        assert!(matches!(
            PackedSlice::new(&packed, values.len(), 57),
            Err(BitpackError::BadWidth(57))
        ));
    }

    #[test]
    fn zero_width_column_reads_zero_with_empty_payload() {
        let slice = PackedSlice::new(&[], 1000, 0).unwrap();
        assert_eq!(slice.len(), 1000);
        assert_eq!(slice.get(999), 0);
    }

    #[test]
    fn fuzz_roundtrip_random_shapes() {
        let mut rng = Rng::new(0xfeed);
        for _ in 0..500 {
            let width = rng.below(MAX_PACKED_WIDTH as usize + 1) as u8;
            let count = rng.below(300);
            let mask = if width == 0 { 0 } else { (1u64 << width) - 1 };
            let values: Vec<u64> = (0..count).map(|_| rng.next_u64() & mask).collect();
            let packed = pack(&values, width);
            let slice = PackedSlice::new(&packed, count, width).unwrap();
            // Random-access order, not just sequential.
            for _ in 0..count.min(64) {
                let i = rng.below(count);
                assert_eq!(slice.get(i), values[i]);
            }
            assert_eq!(slice.to_vec(), values);
        }
    }
}
