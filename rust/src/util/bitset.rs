//! Dense fixed-capacity bitset over `u64` words.
//!
//! Used by the vertical miners (ECLAT, the Apriori bitset counter) to store
//! per-item transaction-id lists, and by the synthetic generators. Hot
//! operations are `and_count` (intersection cardinality without
//! materializing) and in-place intersection — both branch-free loops the
//! compiler auto-vectorizes.

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitset {
    words: Vec<u64>,
    /// Logical capacity in bits; trailing bits beyond `len` are kept zero.
    len: usize,
}

impl Bitset {
    /// All-zeros bitset with capacity for `len` bits.
    pub fn new(len: usize) -> Self {
        Self {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// `|self & other|` without allocating.
    pub fn and_count(&self, other: &Bitset) -> usize {
        debug_assert_eq!(self.len, other.len);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// `self &= other`.
    pub fn and_assign(&mut self, other: &Bitset) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// New bitset `self & other`.
    pub fn and(&self, other: &Bitset) -> Bitset {
        let mut out = self.clone();
        out.and_assign(other);
        out
    }

    /// `self |= other`.
    pub fn or_assign(&mut self, other: &Bitset) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Intersection cardinality of many bitsets (used for itemset support).
    pub fn multi_and_count(sets: &[&Bitset]) -> usize {
        match sets {
            [] => 0,
            [one] => one.count(),
            [first, rest @ ..] => {
                let words = first.words.len();
                let mut total = 0usize;
                for w in 0..words {
                    let mut acc = first.words[w];
                    for s in rest {
                        acc &= s.words[w];
                        if acc == 0 {
                            break;
                        }
                    }
                    total += acc.count_ones() as usize;
                }
                total
            }
        }
    }

    /// Iterator over set-bit indices, ascending.
    pub fn iter_ones(&self) -> Ones<'_> {
        Ones {
            words: &self.words,
            word_idx: 0,
            current: self.words.first().copied().unwrap_or(0),
        }
    }
}

/// Iterator over set bits.
pub struct Ones<'a> {
    words: &'a [u64],
    word_idx: usize,
    current: u64,
}

impl Iterator for Ones<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        loop {
            if self.current != 0 {
                let bit = self.current.trailing_zeros() as usize;
                self.current &= self.current - 1;
                return Some(self.word_idx * 64 + bit);
            }
            self.word_idx += 1;
            if self.word_idx >= self.words.len() {
                return None;
            }
            self.current = self.words[self.word_idx];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = Bitset::new(130);
        assert!(!b.get(0));
        b.set(0);
        b.set(63);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(63) && b.get(64) && b.get(129));
        assert_eq!(b.count(), 4);
        b.clear(63);
        assert!(!b.get(63));
        assert_eq!(b.count(), 3);
    }

    #[test]
    fn and_count_matches_materialized() {
        let mut a = Bitset::new(200);
        let mut b = Bitset::new(200);
        for i in (0..200).step_by(3) {
            a.set(i);
        }
        for i in (0..200).step_by(5) {
            b.set(i);
        }
        let m = a.and(&b);
        assert_eq!(a.and_count(&b), m.count());
        // multiples of 15 under 200: 0,15,...,195 -> 14
        assert_eq!(m.count(), 14);
    }

    #[test]
    fn multi_and_count() {
        let mut a = Bitset::new(100);
        let mut b = Bitset::new(100);
        let mut c = Bitset::new(100);
        for i in 0..100 {
            if i % 2 == 0 {
                a.set(i);
            }
            if i % 3 == 0 {
                b.set(i);
            }
            if i % 5 == 0 {
                c.set(i);
            }
        }
        // multiples of 30 under 100: 0, 30, 60, 90
        assert_eq!(Bitset::multi_and_count(&[&a, &b, &c]), 4);
        assert_eq!(Bitset::multi_and_count(&[&a]), 50);
        assert_eq!(Bitset::multi_and_count(&[]), 0);
    }

    #[test]
    fn iter_ones_ascending() {
        let mut b = Bitset::new(300);
        let idx = [0usize, 1, 63, 64, 65, 127, 128, 255, 299];
        for &i in &idx {
            b.set(i);
        }
        let got: Vec<usize> = b.iter_ones().collect();
        assert_eq!(got, idx);
    }

    #[test]
    fn or_assign() {
        let mut a = Bitset::new(70);
        let mut b = Bitset::new(70);
        a.set(1);
        b.set(69);
        a.or_assign(&b);
        assert!(a.get(1) && a.get(69));
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn empty_bitset() {
        let b = Bitset::new(0);
        assert_eq!(b.count(), 0);
        assert_eq!(b.iter_ones().count(), 0);
    }
}
