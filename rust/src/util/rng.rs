//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set has no `rand` crate, so the library ships its own
//! small, well-known generators: [`SplitMix64`] for seeding and
//! [`Xoshiro256StarStar`] (Blackman & Vigna) as the workhorse. Everything in
//! the repo that consumes randomness (dataset generators, samplers, property
//! tests, bench workloads) goes through [`Rng`], so a fixed seed reproduces a
//! run bit-for-bit.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256**: fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Xoshiro256StarStar {
    s: [u64; 4],
}

impl Xoshiro256StarStar {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

/// The RNG facade used across the repo.
#[derive(Debug, Clone)]
pub struct Rng {
    inner: Xoshiro256StarStar,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self {
            inner: Xoshiro256StarStar::seed_from_u64(seed),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.inner.next_u64()
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick: unbiased enough for simulation workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // For small k relative to n use rejection; otherwise shuffle a range.
        if k * 4 <= n {
            let mut seen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.below(n);
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        } else {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        }
    }

    /// Geometric-ish basket size: 1 + Binomial-approximated count, truncated.
    pub fn basket_size(&mut self, mean: f64, max: usize) -> usize {
        // Sample from a geometric distribution with the given mean, shifted
        // to start at 1 and truncated at `max`. Matches the long-tailed
        // basket-size histograms of real market-basket data.
        let p = 1.0 / mean.max(1.0);
        let u = self.f64().max(f64::MIN_POSITIVE);
        let g = (u.ln() / (1.0 - p).ln()).floor() as usize + 1;
        g.min(max).max(1)
    }
}

/// Zipf (power-law) sampler over `[0, n)` using the rejection-inversion
/// method of Hörmann & Derflinger — the classic item-popularity model for
/// market-basket data.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: usize,
    exponent: f64,
    // Precomputed CDF for exactness at small n (we only need n <= ~4096).
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, exponent: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(exponent);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        Self { n, exponent, cdf }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn exponent(&self) -> f64 {
        self.exponent
    }

    /// Sample a rank in `[0, n)`; rank 0 is the most popular.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.n - 1),
        }
    }

    /// Relative popularity of rank `k` (normalized to sum to 1).
    pub fn pmf(&self, k: usize) -> f64 {
        let prev = if k == 0 { 0.0 } else { self.cdf[k - 1] };
        self.cdf[k] - prev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values from the public-domain splitmix64.c with seed 0.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10)] += 1;
        }
        for &c in &counts {
            // expect ~10_000 each; allow 15% slack
            assert!((8_500..11_500).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(5);
        for k in [0, 1, 10, 50, 100] {
            let s = rng.sample_indices(100, k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
        }
    }

    #[test]
    fn zipf_head_is_heavier() {
        let z = Zipf::new(100, 1.1);
        let mut rng = Rng::new(11);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[80]);
    }

    #[test]
    fn zipf_pmf_sums_to_one() {
        let z = Zipf::new(50, 0.9);
        let total: f64 = (0..50).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn basket_size_bounds() {
        let mut rng = Rng::new(13);
        for _ in 0..1000 {
            let s = rng.basket_size(4.4, 32);
            assert!((1..=32).contains(&s));
        }
    }
}
