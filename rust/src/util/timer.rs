//! Timing helpers shared by the bench harness and pipeline telemetry.

use std::time::{Duration, Instant};

/// Measure the wall-clock time of `f`, returning `(result, elapsed)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// A resettable stopwatch accumulating named laps (stage timings).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, Duration)>,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
            laps: Vec::new(),
        }
    }

    /// Record the time since the previous lap (or start) under `name`.
    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.start;
        self.start = now;
        self.laps.push((name.to_string(), d));
        d
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }

    pub fn total(&self) -> Duration {
        self.laps.iter().map(|(_, d)| *d).sum()
    }
}

/// Human format for durations: picks ns/µs/ms/s.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_value() {
        let (v, d) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0 || d.is_zero()); // just exercises the path
    }

    #[test]
    fn stopwatch_accumulates_laps() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(1));
        sw.lap("a");
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert_eq!(sw.laps()[0].0, "a");
        assert!(sw.total() >= Duration::from_millis(1));
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(5)), "5 ns");
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
    }
}
