//! Injectable filesystem layer for the durability plane.
//!
//! Every durable artifact (WAL, snapshots, manifest, checkpoints) is
//! written through the [`Vfs`] trait rather than `std::fs` directly, so
//! the chaos harness can substitute [`MemVfs`] — an in-memory filesystem
//! that models the page cache (`logical` bytes the running process sees
//! vs `durable` bytes guaranteed to survive a crash), injects
//! deterministic faults (ENOSPC-style write failures, read errors), and
//! simulates kill -9 at an exact I/O-operation index with torn tails on
//! unsynced data. [`RealVfs`] is the production passthrough to `std::fs`.
//!
//! Crash model (matches how the durability plane actually touches disk —
//! append-only logs plus write-temp/fsync/rename snapshots):
//! - bytes acknowledged by `sync_all` survive the crash;
//! - unsynced appended bytes survive as a torn prefix of random length;
//! - an unsynced freshly-created file survives as either nothing, a torn
//!   prefix, or (if it replaced an older synced file) the old content;
//! - `rename` is atomic and durable (journaled-metadata assumption that
//!   write-temp + fsync + rename relies on everywhere).

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::util::rng::Rng;

/// A file handle from a [`Vfs`]: sequential read/write plus durability.
pub trait VfsFile: Read + Write + Send {
    /// Flush application + OS buffers; on return the bytes written so
    /// far are guaranteed to survive a crash.
    fn sync_all(&mut self) -> io::Result<()>;
}

/// The file operations the WAL and snapshot writers need. Object-safe so
/// the plane can hold an `Arc<dyn Vfs>`.
pub trait Vfs: Send + Sync {
    /// Create (truncate) a file for writing.
    fn create(&self, path: &Path) -> io::Result<Box<dyn VfsFile>>;
    /// Open an existing file for reading.
    fn open(&self, path: &Path) -> io::Result<Box<dyn VfsFile>>;
    /// Open (or create) a file for appending.
    fn open_append(&self, path: &Path) -> io::Result<Box<dyn VfsFile>>;
    /// Atomically replace `to` with `from`.
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;
    /// Delete a file (ok if the delete is durable immediately).
    fn remove(&self, path: &Path) -> io::Result<()>;
    fn exists(&self, path: &Path) -> bool;
    fn create_dir_all(&self, path: &Path) -> io::Result<()>;

    /// Read a whole file. Default: `open` + `read_to_end`.
    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        let mut f = self.open(path)?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Ok(buf)
    }

    /// Map a whole file for read-only zero-copy access. The default
    /// implementation reads the file into an 8-byte-aligned in-memory
    /// buffer, so *every* Vfs supports mapping — in particular [`MemVfs`],
    /// which keeps the chaos/fault harness covering the mmap code path.
    /// [`RealVfs`] overrides this with a true `mmap(2)` on unix.
    fn mmap(&self, path: &Path) -> io::Result<MapRegion> {
        Ok(MapRegion::from_bytes(&self.read(path)?))
    }
}

/// Whole-file read-only mapping returned by [`Vfs::mmap`]. Derefs to the
/// file bytes; the base address is guaranteed at least 8-byte aligned
/// (page-aligned for real mappings), which the v4 snapshot layout relies
/// on for zero-copy `f64`/`u64` column views at 64-byte file offsets.
pub struct MapRegion {
    inner: MapInner,
}

enum MapInner {
    Mem(AlignedBuf),
    #[cfg(unix)]
    Real(RealMap),
}

impl MapRegion {
    /// Build a region from a byte image (default Vfs path and tests).
    pub fn from_bytes(bytes: &[u8]) -> Self {
        MapRegion {
            inner: MapInner::Mem(AlignedBuf::from_bytes(bytes)),
        }
    }

    /// True when backed by a kernel mapping (pages shared with the page
    /// cache) rather than a private in-memory copy.
    pub fn is_kernel_mapping(&self) -> bool {
        match self.inner {
            MapInner::Mem(_) => false,
            #[cfg(unix)]
            MapInner::Real(_) => true,
        }
    }
}

impl std::ops::Deref for MapRegion {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        match &self.inner {
            MapInner::Mem(buf) => buf.as_bytes(),
            #[cfg(unix)]
            MapInner::Real(map) => map.as_bytes(),
        }
    }
}

impl std::fmt::Debug for MapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MapRegion")
            .field("len", &self.len())
            .field("kernel", &self.is_kernel_mapping())
            .finish()
    }
}

/// A byte buffer whose base address is 8-byte aligned (it borrows a
/// `Vec<u64>`'s allocation), emulating the alignment a page-aligned mmap
/// gives for free.
struct AlignedBuf {
    storage: Vec<u64>,
    len: usize,
}

impl AlignedBuf {
    fn from_bytes(bytes: &[u8]) -> Self {
        let mut storage = vec![0u64; bytes.len().div_ceil(8)];
        for (i, chunk) in bytes.chunks(8).enumerate() {
            let mut word = [0u8; 8];
            word[..chunk.len()].copy_from_slice(chunk);
            storage[i] = u64::from_le_bytes(word);
        }
        AlignedBuf {
            storage,
            len: bytes.len(),
        }
    }

    fn as_bytes(&self) -> &[u8] {
        // Sound: the Vec<u64> allocation is valid for `len <= 8 * words`
        // bytes and u64 has no padding or invalid byte patterns.
        unsafe { std::slice::from_raw_parts(self.storage.as_ptr() as *const u8, self.len) }
    }
}

/// Raw kernel mapping (unix). Read-only and private; unmapped on drop.
#[cfg(unix)]
struct RealMap {
    ptr: *mut u8,
    len: usize,
}

#[cfg(unix)]
impl RealMap {
    fn as_bytes(&self) -> &[u8] {
        // Sound: `ptr` came from a successful PROT_READ mmap of `len`
        // bytes and lives until Drop; the mapping is never written.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

// The mapping is immutable shared memory: safe to read from any thread.
#[cfg(unix)]
unsafe impl Send for RealMap {}
#[cfg(unix)]
unsafe impl Sync for RealMap {}

#[cfg(unix)]
impl Drop for RealMap {
    fn drop(&mut self) {
        unsafe {
            sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
        }
    }
}

/// Minimal direct bindings for `mmap(2)`/`munmap(2)` — the offline vendor
/// set has no `libc` crate.
#[cfg(unix)]
mod sys {
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut std::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut std::ffi::c_void;
        pub fn munmap(addr: *mut std::ffi::c_void, len: usize) -> i32;
    }
}

/// Production passthrough to `std::fs`.
#[derive(Debug, Clone, Copy, Default)]
pub struct RealVfs;

struct RealFile(std::fs::File);

impl Read for RealFile {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        self.0.read(buf)
    }
}

impl Write for RealFile {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.write(buf)
    }
    fn flush(&mut self) -> io::Result<()> {
        self.0.flush()
    }
}

impl VfsFile for RealFile {
    fn sync_all(&mut self) -> io::Result<()> {
        self.0.sync_all()
    }
}

impl Vfs for RealVfs {
    fn create(&self, path: &Path) -> io::Result<Box<dyn VfsFile>> {
        Ok(Box::new(RealFile(std::fs::File::create(path)?)))
    }
    fn open(&self, path: &Path) -> io::Result<Box<dyn VfsFile>> {
        Ok(Box::new(RealFile(std::fs::File::open(path)?)))
    }
    fn open_append(&self, path: &Path) -> io::Result<Box<dyn VfsFile>> {
        let f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        Ok(Box::new(RealFile(f)))
    }
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        std::fs::rename(from, to)
    }
    fn remove(&self, path: &Path) -> io::Result<()> {
        std::fs::remove_file(path)
    }
    fn exists(&self, path: &Path) -> bool {
        path.exists()
    }
    fn create_dir_all(&self, path: &Path) -> io::Result<()> {
        std::fs::create_dir_all(path)
    }

    /// True zero-copy mapping: snapshot pages stay in the kernel page
    /// cache and are shared across processes serving the same file.
    #[cfg(unix)]
    fn mmap(&self, path: &Path) -> io::Result<MapRegion> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path)?;
        let len = f.metadata()?.len() as usize;
        if len == 0 {
            return Ok(MapRegion::from_bytes(&[]));
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr.is_null() || ptr as isize == -1 {
            // MAP_FAILED (e.g. a filesystem without mmap support): degrade
            // to the aligned-read emulation rather than failing the open.
            return Ok(MapRegion::from_bytes(&std::fs::read(path)?));
        }
        Ok(MapRegion {
            inner: MapInner::Real(RealMap {
                ptr: ptr as *mut u8,
                len,
            }),
        })
    }
}

fn injected(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::Other, format!("injected fault: {msg}"))
}

fn crashed_err() -> io::Error {
    io::Error::new(io::ErrorKind::Other, "simulated crash: filesystem down")
}

#[derive(Debug, Default, Clone)]
struct MemFileState {
    /// Bytes guaranteed to survive a crash (synced).
    durable: Vec<u8>,
    /// Bytes the running process sees (page cache view).
    logical: Vec<u8>,
}

#[derive(Debug, Default)]
struct MemInner {
    files: BTreeMap<PathBuf, MemFileState>,
    /// Monotone count of vfs operations (reads, writes, syncs, metadata).
    ops: u64,
    /// Op index → error message: that op fails with an injected error.
    fail_ops: BTreeMap<u64, String>,
    /// Any mutating op on a path containing this substring fails.
    fail_path_substr: Option<String>,
    /// Simulate kill -9 when the op counter reaches this index.
    crash_at_op: Option<u64>,
    crashed: bool,
    torn_rng: Rng,
}

impl MemInner {
    /// Compute the post-crash disk image of one file.
    fn crash_file(st: &mut MemFileState, rng: &mut Rng) {
        if st.logical == st.durable {
            return;
        }
        if st.logical.len() >= st.durable.len() && st.logical.starts_with(&st.durable) {
            // Pure append since the last sync: a torn prefix of the
            // unsynced suffix survives.
            let start = st.durable.len();
            let keep = rng.below(st.logical.len() - start + 1);
            let tail = st.logical[start..start + keep].to_vec();
            st.durable.extend_from_slice(&tail);
        } else if rng.below(2) == 0 {
            // Unsynced rewrite: old synced content survives.
        } else {
            // ... or a torn prefix of the new content does.
            let keep = rng.below(st.logical.len() + 1);
            st.durable = st.logical[..keep].to_vec();
        }
        st.logical = st.durable.clone();
    }

    fn crash_now(&mut self) {
        self.crashed = true;
        self.crash_at_op = None;
        let mut rng = Rng::new(self.torn_rng.next_u64());
        for st in self.files.values_mut() {
            Self::crash_file(st, &mut rng);
        }
        // Zero-length survivors of an unsynced create: drop them, like a
        // file whose directory entry never reached the journal.
        self.files.retain(|_, st| !st.durable.is_empty());
    }

    /// Account one op; returns an error if this op is faulted or the fs
    /// has already crashed. `mutates` + `path` drive path-substring
    /// faults (used to model a full/broken device for one artifact).
    fn tick(&mut self, mutates: bool, path: Option<&Path>) -> io::Result<()> {
        if self.crashed {
            return Err(crashed_err());
        }
        self.ops += 1;
        let op = self.ops;
        if self.crash_at_op == Some(op) {
            self.crash_now();
            return Err(crashed_err());
        }
        if let Some(msg) = self.fail_ops.remove(&op) {
            return Err(injected(&msg));
        }
        if mutates {
            if let (Some(substr), Some(p)) = (self.fail_path_substr.as_ref(), path) {
                if p.to_string_lossy().contains(substr.as_str()) {
                    return Err(injected(&format!("write to {} refused", p.display())));
                }
            }
        }
        Ok(())
    }
}

/// Deterministic in-memory filesystem with crash & fault simulation.
/// Cheap to clone (shared state).
#[derive(Clone)]
pub struct MemVfs {
    inner: Arc<Mutex<MemInner>>,
}

impl Default for MemVfs {
    fn default() -> Self {
        Self::new(0)
    }
}

impl MemVfs {
    pub fn new(torn_seed: u64) -> Self {
        Self {
            inner: Arc::new(Mutex::new(MemInner {
                torn_rng: Rng::new(torn_seed ^ 0x746F_725F_6D66_7321),
                ..Default::default()
            })),
        }
    }

    /// Total vfs operations performed so far.
    pub fn ops(&self) -> u64 {
        self.inner.lock().unwrap().ops
    }

    /// Make op number `op` (1-based, compared against [`MemVfs::ops`])
    /// fail with an injected error.
    pub fn fail_op(&self, op: u64, msg: &str) {
        self.inner.lock().unwrap().fail_ops.insert(op, msg.to_string());
    }

    /// Fail every mutating op whose path contains `substr` (models a
    /// persistently failing device for that artifact). Pass `None` to
    /// clear.
    pub fn fail_path_containing(&self, substr: Option<&str>) {
        self.inner.lock().unwrap().fail_path_substr = substr.map(|s| s.to_string());
    }

    /// Kill the filesystem when the op counter reaches `op`: unsynced
    /// data is torn deterministically and every subsequent op errors
    /// until [`MemVfs::recover`].
    pub fn crash_at_op(&self, op: u64) {
        self.inner.lock().unwrap().crash_at_op = Some(op);
    }

    /// Crash immediately (same tearing semantics as [`MemVfs::crash_at_op`]).
    pub fn crash_now(&self) {
        self.inner.lock().unwrap().crash_now();
    }

    pub fn is_crashed(&self) -> bool {
        self.inner.lock().unwrap().crashed
    }

    /// "Reboot": the post-crash disk image becomes the live filesystem.
    pub fn recover(&self) {
        let mut g = self.inner.lock().unwrap();
        g.crashed = false;
        g.crash_at_op = None;
        for st in g.files.values_mut() {
            st.logical = st.durable.clone();
        }
    }

    /// Paths currently present (live view).
    pub fn list(&self) -> Vec<PathBuf> {
        self.inner.lock().unwrap().files.keys().cloned().collect()
    }

    /// Flip one bit of a file in place (corruption injection for loader
    /// hardening tests).
    pub fn flip_bit(&self, path: &Path, byte: usize, bit: u8) {
        let mut g = self.inner.lock().unwrap();
        if let Some(st) = g.files.get_mut(path) {
            if byte < st.logical.len() {
                st.logical[byte] ^= 1 << (bit & 7);
            }
            if byte < st.durable.len() {
                st.durable[byte] ^= 1 << (bit & 7);
            }
        }
    }
}

enum MemMode {
    Read,
    Write,
}

struct MemFile {
    vfs: Arc<Mutex<MemInner>>,
    path: PathBuf,
    mode: MemMode,
    pos: usize,
}

impl Read for MemFile {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if matches!(self.mode, MemMode::Write) {
            return Err(io::Error::new(
                io::ErrorKind::Other,
                "MemVfs handle opened write-only",
            ));
        }
        let mut g = self.vfs.lock().unwrap();
        g.tick(false, Some(&self.path))?;
        let st = g
            .files
            .get(&self.path)
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "file vanished"))?;
        let data = &st.logical;
        if self.pos >= data.len() {
            return Ok(0);
        }
        let n = buf.len().min(data.len() - self.pos);
        buf[..n].copy_from_slice(&data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

impl Write for MemFile {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if matches!(self.mode, MemMode::Read) {
            return Err(io::Error::new(
                io::ErrorKind::Other,
                "MemVfs handle opened read-only",
            ));
        }
        let mut g = self.vfs.lock().unwrap();
        g.tick(true, Some(&self.path))?;
        let st = g.files.entry(self.path.clone()).or_default();
        st.logical.extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

impl VfsFile for MemFile {
    fn sync_all(&mut self) -> io::Result<()> {
        let mut g = self.vfs.lock().unwrap();
        g.tick(true, Some(&self.path))?;
        if let Some(st) = g.files.get_mut(&self.path) {
            st.durable = st.logical.clone();
        }
        Ok(())
    }
}

impl Vfs for MemVfs {
    fn create(&self, path: &Path) -> io::Result<Box<dyn VfsFile>> {
        let mut g = self.inner.lock().unwrap();
        g.tick(true, Some(path))?;
        let st = g.files.entry(path.to_path_buf()).or_default();
        st.logical.clear();
        Ok(Box::new(MemFile {
            vfs: Arc::clone(&self.inner),
            path: path.to_path_buf(),
            mode: MemMode::Write,
            pos: 0,
        }))
    }

    fn open(&self, path: &Path) -> io::Result<Box<dyn VfsFile>> {
        let mut g = self.inner.lock().unwrap();
        g.tick(false, Some(path))?;
        if !g.files.contains_key(path) {
            return Err(io::Error::new(io::ErrorKind::NotFound, "no such file"));
        }
        Ok(Box::new(MemFile {
            vfs: Arc::clone(&self.inner),
            path: path.to_path_buf(),
            mode: MemMode::Read,
            pos: 0,
        }))
    }

    fn open_append(&self, path: &Path) -> io::Result<Box<dyn VfsFile>> {
        let mut g = self.inner.lock().unwrap();
        g.tick(true, Some(path))?;
        g.files.entry(path.to_path_buf()).or_default();
        Ok(Box::new(MemFile {
            vfs: Arc::clone(&self.inner),
            path: path.to_path_buf(),
            mode: MemMode::Write,
            pos: 0,
        }))
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        let mut g = self.inner.lock().unwrap();
        g.tick(true, Some(to))?;
        let st = g
            .files
            .remove(from)
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "rename source missing"))?;
        // Atomic + durable: the renamed file carries its logical content
        // as the durable image (rename barriers the journal).
        let durable = st.logical.clone();
        g.files.insert(
            to.to_path_buf(),
            MemFileState {
                durable,
                logical: st.logical,
            },
        );
        Ok(())
    }

    fn remove(&self, path: &Path) -> io::Result<()> {
        let mut g = self.inner.lock().unwrap();
        g.tick(true, Some(path))?;
        g.files
            .remove(path)
            .map(|_| ())
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "remove target missing"))
    }

    fn exists(&self, path: &Path) -> bool {
        self.inner.lock().unwrap().files.contains_key(path)
    }

    fn create_dir_all(&self, _path: &Path) -> io::Result<()> {
        let mut g = self.inner.lock().unwrap();
        g.tick(true, None)
    }
}

/// Crash-safe whole-file write: temp file in the same directory, fsync,
/// atomic rename over the destination. A crash at any point leaves
/// either the old file or the new file — never a torn mix.
pub fn atomic_write_with<F>(vfs: &dyn Vfs, path: &Path, write: F) -> io::Result<()>
where
    F: FnOnce(&mut dyn Write) -> io::Result<()>,
{
    let tmp = tmp_path(path);
    let mut f = vfs.create(&tmp)?;
    {
        let mut buf = io::BufWriter::new(&mut f);
        write(&mut buf)?;
        buf.flush()?;
    }
    f.sync_all()?;
    drop(f);
    vfs.rename(&tmp, path)
}

/// Sibling temp path used by [`atomic_write_with`].
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_vfs_roundtrip_and_exists() {
        let vfs = MemVfs::new(1);
        let p = Path::new("a/b.bin");
        assert!(!vfs.exists(p));
        let mut f = vfs.create(p).unwrap();
        f.write_all(b"hello").unwrap();
        f.sync_all().unwrap();
        drop(f);
        assert!(vfs.exists(p));
        assert_eq!(vfs.read(p).unwrap(), b"hello");
    }

    #[test]
    fn unsynced_append_is_torn_on_crash() {
        for seed in 0..32u64 {
            let vfs = MemVfs::new(seed);
            let p = Path::new("wal.log");
            let mut f = vfs.create(p).unwrap();
            f.write_all(b"AAAA").unwrap();
            f.sync_all().unwrap();
            f.write_all(b"BBBBBBBB").unwrap();
            drop(f);
            vfs.crash_now();
            vfs.recover();
            let got = vfs.read(p).unwrap();
            assert!(got.len() >= 4 && got.len() <= 12, "len {}", got.len());
            assert_eq!(&got[..4], b"AAAA");
            assert!(got[4..].iter().all(|&b| b == b'B'));
        }
    }

    #[test]
    fn rename_is_atomic_and_durable() {
        let vfs = MemVfs::new(7);
        let old = Path::new("snap.tor");
        let mut f = vfs.create(old).unwrap();
        f.write_all(b"OLD").unwrap();
        f.sync_all().unwrap();
        drop(f);

        let tmp = Path::new("snap.tor.tmp");
        let mut f = vfs.create(tmp).unwrap();
        f.write_all(b"NEWNEW").unwrap();
        f.sync_all().unwrap();
        drop(f);
        vfs.rename(tmp, old).unwrap();
        vfs.crash_now();
        vfs.recover();
        assert_eq!(vfs.read(old).unwrap(), b"NEWNEW");
        assert!(!vfs.exists(tmp));
    }

    #[test]
    fn unsynced_rewrite_keeps_old_or_torn_new() {
        for seed in 0..32u64 {
            let vfs = MemVfs::new(seed);
            let p = Path::new("x.bin");
            let mut f = vfs.create(p).unwrap();
            f.write_all(b"OLDOLD").unwrap();
            f.sync_all().unwrap();
            drop(f);
            let mut f = vfs.create(p).unwrap();
            f.write_all(b"NEW").unwrap();
            drop(f); // no sync
            vfs.crash_now();
            vfs.recover();
            let got = vfs.read(p).unwrap_or_default();
            let ok = got == b"OLDOLD" || b"NEW".starts_with(&got[..]);
            assert!(ok, "unexpected post-crash content {got:?}");
        }
    }

    #[test]
    fn injected_op_fault_fires_once() {
        let vfs = MemVfs::new(3);
        let p = Path::new("w.bin");
        let mut f = vfs.create(p).unwrap();
        vfs.fail_op(vfs.ops() + 1, "disk full");
        let err = f.write_all(b"x").unwrap_err();
        assert!(err.to_string().contains("injected fault"));
        f.write_all(b"x").unwrap();
    }

    #[test]
    fn path_fault_blocks_writes_but_not_reads() {
        let vfs = MemVfs::new(4);
        let p = Path::new("dir/wal.log");
        let mut f = vfs.create(p).unwrap();
        f.write_all(b"ok").unwrap();
        f.sync_all().unwrap();
        vfs.fail_path_containing(Some("wal.log"));
        assert!(f.write_all(b"no").is_err());
        assert_eq!(vfs.read(p).unwrap(), b"ok");
        vfs.fail_path_containing(None);
        f.write_all(b"yes").unwrap();
    }

    #[test]
    fn atomic_write_replaces_only_on_success() {
        let vfs = MemVfs::new(5);
        let p = Path::new("m/MANIFEST");
        atomic_write_with(&vfs, p, |w| w.write_all(b"v1")).unwrap();
        assert_eq!(vfs.read(p).unwrap(), b"v1");
        // Fail the rename (last mutating op of the sequence): old content
        // must survive.
        let r = atomic_write_with(&vfs, p, |w| {
            w.write_all(b"v2")?;
            Err(io::Error::new(io::ErrorKind::Other, "writer bailed"))
        });
        assert!(r.is_err());
        assert_eq!(vfs.read(p).unwrap(), b"v1");
    }

    #[test]
    fn crash_after_ops_counts_deterministically() {
        let run = |crash_at: Option<u64>| -> (u64, Vec<u8>) {
            let vfs = MemVfs::new(9);
            if let Some(k) = crash_at {
                vfs.crash_at_op(k);
            }
            let p = Path::new("wal");
            let mut f = match vfs.create(p) {
                Ok(f) => f,
                Err(_) => return (vfs.ops(), Vec::new()),
            };
            for chunk in 0..4 {
                if f.write_all(&[chunk as u8; 8]).is_err() {
                    break;
                }
                if f.sync_all().is_err() {
                    break;
                }
            }
            drop(f);
            if vfs.is_crashed() {
                vfs.recover();
            }
            (vfs.ops(), vfs.read(p).unwrap_or_default())
        };
        let (total, full) = run(None);
        assert_eq!(full.len(), 32);
        for k in 1..=total {
            let (_, got) = run(Some(k));
            // Every synced 8-byte chunk before the crash survives intact.
            let synced = got.len() / 8 * 8;
            assert_eq!(&got[..synced], &full[..synced]);
        }
    }

    #[test]
    fn mem_vfs_mmap_matches_read_and_is_aligned() {
        let vfs = MemVfs::new(21);
        let p = Path::new("snap.tor");
        for len in [0usize, 1, 7, 8, 9, 64, 1000] {
            let data: Vec<u8> = (0..len).map(|i| (i * 31) as u8).collect();
            let mut f = vfs.create(p).unwrap();
            f.write_all(&data).unwrap();
            f.sync_all().unwrap();
            drop(f);
            let region = vfs.mmap(p).unwrap();
            assert_eq!(&region[..], &data[..], "len {len}");
            assert_eq!(region.as_ptr() as usize % 8, 0, "base alignment");
            assert!(!region.is_kernel_mapping());
        }
    }

    #[test]
    fn mem_vfs_mmap_missing_file_and_faults_propagate() {
        let vfs = MemVfs::new(22);
        assert!(vfs.mmap(Path::new("absent")).is_err());
        let p = Path::new("present");
        let mut f = vfs.create(p).unwrap();
        f.write_all(b"data").unwrap();
        f.sync_all().unwrap();
        drop(f);
        // Fault the open op driven by the default mmap impl.
        vfs.fail_op(vfs.ops() + 1, "mmap read refused");
        let err = vfs.mmap(p).unwrap_err();
        assert!(err.to_string().contains("injected fault"));
        assert_eq!(&vfs.mmap(p).unwrap()[..], b"data");
    }

    #[cfg(unix)]
    #[test]
    fn real_vfs_mmap_maps_file_contents() {
        let dir = std::env::temp_dir().join(format!("tor_fsio_mmap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("region.bin");
        let data: Vec<u8> = (0..10_000u32).flat_map(|i| i.to_le_bytes()).collect();
        std::fs::write(&path, &data).unwrap();
        let region = RealVfs.mmap(&path).unwrap();
        assert_eq!(&region[..], &data[..]);
        assert!(region.is_kernel_mapping());
        assert_eq!(region.as_ptr() as usize % 8, 0);
        // Region stays valid after the file handle is long gone; empty
        // files map to empty regions instead of erroring.
        std::fs::write(&path, b"").unwrap();
        let empty = RealVfs.mmap(&path).unwrap();
        assert!(empty.is_empty());
        drop(region);
        std::fs::remove_dir_all(&dir).ok();
    }
}
