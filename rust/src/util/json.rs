//! Minimal JSON reader/writer (no `serde` in the offline vendor set).
//!
//! Reader: enough of RFC 8259 to parse `artifacts/manifest.json` and bench
//! result files — objects, arrays, strings (with escapes), numbers, bools,
//! null. Writer: streaming builder used by the bench harness to emit result
//! rows.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    // JSON has no representation for NaN/Infinity; emitting them would
    // corrupt every downstream reader (including our own parser). Sanitize
    // to null, mirroring what serde_json's `arbitrary_precision`-less
    // serializers reject outright.
    if !n.is_finite() {
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'u') => {
                        let hex = self
                            .bytes
                            .get(self.pos..self.pos + 4)
                            .ok_or_else(|| self.err("bad \\u escape"))?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|_| self.err("bad \\u escape"))?,
                            16,
                        )
                        .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        // BMP only; surrogate pairs unsupported (not needed
                        // for manifests / bench rows).
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8.
                    let start = self.pos - 1;
                    let width = utf8_width(b);
                    let end = start + width;
                    let chunk = self
                        .bytes
                        .get(start..end)
                        .ok_or_else(|| self.err("bad utf8"))?;
                    let cs = std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?;
                    s.push_str(cs);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_width(b: u8) -> usize {
    match b {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"ok":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let out = Json::Num(bad).to_string_compact();
            assert_eq!(out, "null", "non-finite {bad} must sanitize");
            // The sanitized output must round-trip through our own parser.
            assert_eq!(Json::parse(&out).unwrap(), Json::Null);
        }
        let nested = Json::Arr(vec![Json::Num(1.0), Json::Num(f64::NAN)]);
        assert_eq!(nested.to_string_compact(), "[1,null]");
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let v = Json::parse(r#""é café é""#).unwrap();
        assert_eq!(v.as_str(), Some("é café é"));
    }

    #[test]
    fn parses_real_manifest() {
        // Shape of python/compile/aot.py output.
        let src = r#"{
          "format": "hlo-text",
          "return_tuple": true,
          "shapes": {"nt": 4096, "ni": 256, "nk": 256, "nr": 1024},
          "artifacts": {
            "support_count": {"file": "support_count.hlo.txt",
                              "inputs": [[4096,256],[256,256],[256]],
                              "num_outputs": 1, "bytes": 5664}
          }
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("shapes").unwrap().get("nt").unwrap().as_usize(), Some(4096));
        let art = v.get("artifacts").unwrap().get("support_count").unwrap();
        assert_eq!(art.get("num_outputs").unwrap().as_usize(), Some(1));
        assert_eq!(
            art.get("inputs").unwrap().as_arr().unwrap()[0].as_arr().unwrap()[1].as_usize(),
            Some(256)
        );
    }
}
