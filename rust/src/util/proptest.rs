//! Miniature property-based testing harness.
//!
//! The offline vendor set has no `proptest`/`quickcheck`, so the repo ships
//! its own: a [`Gen`] wrapper around [`crate::util::rng::Rng`] plus
//! [`for_all`], which runs a property over `n` random cases and, on failure,
//! greedily shrinks the failing input via a user-supplied shrink function
//! before panicking with the minimal counterexample.
//!
//! Used by the trie/mining invariant tests (DESIGN.md E9 and friends).

use crate::util::rng::Rng;

/// Test-case generator context.
pub struct Gen {
    rng: Rng,
    /// Size hint: generators should scale collection sizes by this.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self {
            rng: Rng::new(seed),
            size,
        }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// A vector of `len` items drawn by `f`, `len` in `[0, size]`.
    pub fn vec_of<T>(&mut self, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.rng.below(self.size + 1);
        (0..len).map(|_| f(self)).collect()
    }
}

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` random inputs produced by `make`.
///
/// On failure, `shrink` is called repeatedly: it must return a list of
/// strictly "smaller" candidate inputs; the first candidate that still fails
/// becomes the new counterexample, until no candidate fails. Panics with the
/// minimal counterexample (via `fmt`).
pub fn for_all<T: Clone>(
    name: &str,
    cases: usize,
    seed: u64,
    mut make: impl FnMut(&mut Gen) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    fmt: impl Fn(&T) -> String,
    prop: impl Fn(&T) -> PropResult,
) {
    for case in 0..cases {
        let mut g = Gen::new(seed.wrapping_add(case as u64 * 0x9E37), 16);
        let input = make(&mut g);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink loop.
            let mut best = input;
            let mut best_msg = first_msg;
            let mut budget = 1000usize;
            'outer: while budget > 0 {
                for cand in shrink(&best) {
                    budget -= 1;
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  input: {}\n  error: {best_msg}",
                fmt(&best)
            );
        }
    }
}

/// Convenience: shrink a `Vec<T>` by dropping halves, then single elements.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    out.push(v[..n / 2].to_vec());
    out.push(v[n / 2..].to_vec());
    for i in 0..n.min(16) {
        let mut c = v.to_vec();
        c.remove(i);
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        for_all(
            "reverse-reverse",
            50,
            42,
            |g| g.vec_of(|g| g.usize_in(0, 100)),
            |v| shrink_vec(v),
            |v| format!("{v:?}"),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if w == *v {
                    Ok(())
                } else {
                    Err("reverse twice changed vec".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'sum-under-10' failed")]
    fn failing_property_panics_with_counterexample() {
        for_all(
            "sum-under-10",
            100,
            7,
            |g| g.vec_of(|g| g.usize_in(0, 5)),
            |v| shrink_vec(v),
            |v| format!("{v:?}"),
            |v| {
                if v.iter().sum::<usize>() < 10 {
                    Ok(())
                } else {
                    Err(format!("sum = {}", v.iter().sum::<usize>()))
                }
            },
        );
    }

    #[test]
    fn shrinking_reduces_input() {
        // Capture the panic message and assert the counterexample is small:
        // minimal failing vec for "no element >= 3" shrinks to one element.
        let result = std::panic::catch_unwind(|| {
            for_all(
                "no-elem-ge-3",
                100,
                11,
                |g| g.vec_of(|g| g.usize_in(0, 10)),
                |v| shrink_vec(v),
                |v| format!("{v:?}"),
                |v| {
                    if v.iter().all(|&x| x < 3) {
                        Ok(())
                    } else {
                        Err("elem >= 3".into())
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // input line should contain a single-element vec like "[7]"
        let input_line = msg.lines().find(|l| l.contains("input:")).unwrap();
        let open = input_line.find('[').unwrap();
        let close = input_line.find(']').unwrap();
        let body = &input_line[open + 1..close];
        assert!(
            !body.contains(','),
            "expected single-element counterexample, got {input_line}"
        );
    }
}
