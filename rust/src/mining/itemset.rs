//! Itemsets: sorted id vectors with set algebra, plus the mining output
//! container shared by all four miners.

use std::collections::HashMap;

use crate::data::vocab::ItemId;

/// A frequent itemset: item ids sorted ascending, no duplicates.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Itemset(Vec<ItemId>);

impl Itemset {
    /// Construct from arbitrary ids (sorts + dedups).
    pub fn new(mut items: Vec<ItemId>) -> Self {
        items.sort_unstable();
        items.dedup();
        Itemset(items)
    }

    /// Construct from ids already sorted ascending (debug-checked).
    pub fn from_sorted(items: Vec<ItemId>) -> Self {
        debug_assert!(items.windows(2).all(|w| w[0] < w[1]), "not sorted/unique");
        Itemset(items)
    }

    pub fn items(&self) -> &[ItemId] {
        &self.0
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn contains(&self, item: ItemId) -> bool {
        self.0.binary_search(&item).is_ok()
    }

    /// True iff `self ⊆ other` (both sorted; linear merge).
    pub fn is_subset_of(&self, other: &Itemset) -> bool {
        sorted_subset(&self.0, &other.0)
    }

    /// Union (sorted merge).
    pub fn union(&self, other: &Itemset) -> Itemset {
        let mut out = Vec::with_capacity(self.len() + other.len());
        let (mut i, mut j) = (0, 0);
        while i < self.0.len() && j < other.0.len() {
            match self.0[i].cmp(&other.0[j]) {
                std::cmp::Ordering::Less => {
                    out.push(self.0[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push(other.0[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    out.push(self.0[i]);
                    i += 1;
                    j += 1;
                }
            }
        }
        out.extend_from_slice(&self.0[i..]);
        out.extend_from_slice(&other.0[j..]);
        Itemset(out)
    }

    /// Difference `self \ other` (sorted merge).
    pub fn difference(&self, other: &Itemset) -> Itemset {
        let mut out = Vec::with_capacity(self.len());
        let mut j = 0;
        for &x in &self.0 {
            while j < other.0.len() && other.0[j] < x {
                j += 1;
            }
            if j >= other.0.len() || other.0[j] != x {
                out.push(x);
            }
        }
        Itemset(out)
    }

    /// All non-empty proper subsets (for rule generation on small sets).
    pub fn proper_subsets(&self) -> Vec<Itemset> {
        let n = self.0.len();
        assert!(n <= 20, "proper_subsets on an itemset of {n} items");
        let mut out = Vec::with_capacity((1usize << n) - 2);
        for mask in 1..(1u32 << n) - 1 {
            let items: Vec<ItemId> = (0..n)
                .filter(|&b| mask >> b & 1 == 1)
                .map(|b| self.0[b])
                .collect();
            out.push(Itemset(items));
        }
        out
    }
}

/// `a ⊆ b` for sorted unique slices.
pub fn sorted_subset(a: &[ItemId], b: &[ItemId]) -> bool {
    if a.len() > b.len() {
        return false;
    }
    let mut j = 0;
    for &x in a {
        while j < b.len() && b[j] < x {
            j += 1;
        }
        if j >= b.len() || b[j] != x {
            return false;
        }
        j += 1;
    }
    true
}

impl std::fmt::Display for Itemset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{{")?;
        for (i, it) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{it}")?;
        }
        write!(f, "}}")
    }
}

/// Output of a frequent-itemset miner: itemsets with absolute support
/// counts, plus the database size for relative support.
#[derive(Debug, Clone, Default)]
pub struct FrequentItemsets {
    pub num_transactions: usize,
    /// (itemset, absolute support count), no duplicates.
    pub sets: Vec<(Itemset, u64)>,
}

impl FrequentItemsets {
    pub fn len(&self) -> usize {
        self.sets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// Support lookup table.
    pub fn support_map(&self) -> HashMap<Itemset, u64> {
        self.sets.iter().cloned().collect()
    }

    /// Sorted-table support index borrowing this collection — the
    /// allocation-free probe structure rule generation runs on.
    pub fn support_index(&self) -> SupportIndex<'_> {
        SupportIndex::new(self)
    }

    /// Relative support of an entry.
    pub fn rel_support(&self, count: u64) -> f64 {
        count as f64 / self.num_transactions as f64
    }

    /// Sort canonically (by length then lexicographic) — makes miner outputs
    /// directly comparable in tests.
    pub fn canonicalize(&mut self) {
        self.sets
            .sort_by(|a, b| (a.0.len(), &a.0).cmp(&(b.0.len(), &b.0)));
    }
}

/// A binary-searchable support table over a [`FrequentItemsets`], built
/// once and probed with **borrowed** `&[ItemId]` keys — no `Itemset`
/// allocation and no hashing per lookup, unlike
/// [`FrequentItemsets::support_map`]. Entries are ordered by the canonical
/// (length, lexicographic) key, the same total order
/// [`FrequentItemsets::canonicalize`] imposes, so the index is independent
/// of the miner's emission order.
#[derive(Debug, Clone)]
pub struct SupportIndex<'a> {
    /// (items, count), sorted by (len, items); slices borrow the table.
    entries: Vec<(&'a [ItemId], u64)>,
}

impl<'a> SupportIndex<'a> {
    pub fn new(fi: &'a FrequentItemsets) -> Self {
        let mut entries: Vec<(&'a [ItemId], u64)> =
            fi.sets.iter().map(|(s, c)| (s.items(), *c)).collect();
        entries.sort_unstable_by(|a, b| (a.0.len(), a.0).cmp(&(b.0.len(), b.0)));
        SupportIndex { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Absolute support of `items` (sorted ascending, unique), if frequent.
    #[inline]
    pub fn get(&self, items: &[ItemId]) -> Option<u64> {
        debug_assert!(items.windows(2).all(|w| w[0] < w[1]), "key not sorted/unique");
        self.entries
            .binary_search_by(|&(e, _)| (e.len(), e).cmp(&(items.len(), items)))
            .ok()
            .map(|i| self.entries[i].1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_sorts_and_dedups() {
        let s = Itemset::new(vec![3, 1, 3, 2]);
        assert_eq!(s.items(), &[1, 2, 3]);
    }

    #[test]
    fn subset_relation() {
        let a = Itemset::new(vec![1, 3]);
        let b = Itemset::new(vec![1, 2, 3]);
        assert!(a.is_subset_of(&b));
        assert!(!b.is_subset_of(&a));
        assert!(a.is_subset_of(&a));
        assert!(Itemset::new(vec![]).is_subset_of(&a));
        assert!(!Itemset::new(vec![4]).is_subset_of(&b));
    }

    #[test]
    fn union_difference() {
        let a = Itemset::new(vec![1, 2, 5]);
        let b = Itemset::new(vec![2, 3]);
        assert_eq!(a.union(&b).items(), &[1, 2, 3, 5]);
        assert_eq!(a.difference(&b).items(), &[1, 5]);
        assert_eq!(b.difference(&a).items(), &[3]);
    }

    #[test]
    fn proper_subsets_count() {
        let s = Itemset::new(vec![1, 2, 3]);
        let subs = s.proper_subsets();
        assert_eq!(subs.len(), 6); // 2^3 - 2
        assert!(subs.contains(&Itemset::new(vec![1])));
        assert!(subs.contains(&Itemset::new(vec![2, 3])));
        assert!(!subs.contains(&s));
        assert!(!subs.contains(&Itemset::new(vec![])));
    }

    #[test]
    fn display_format() {
        assert_eq!(Itemset::new(vec![2, 1]).to_string(), "{1,2}");
        assert_eq!(Itemset::new(vec![]).to_string(), "{}");
    }

    #[test]
    fn support_index_agrees_with_support_map() {
        // Deliberately non-canonical emission order: the index must not
        // depend on it.
        let fi = FrequentItemsets {
            num_transactions: 10,
            sets: vec![
                (Itemset::new(vec![1, 2]), 3),
                (Itemset::new(vec![2]), 7),
                (Itemset::new(vec![1]), 5),
                (Itemset::new(vec![1, 2, 4]), 2),
                (Itemset::new(vec![4]), 4),
            ],
        };
        let index = fi.support_index();
        assert_eq!(index.len(), fi.len());
        assert!(!index.is_empty());
        let map = fi.support_map();
        for (set, count) in &fi.sets {
            assert_eq!(index.get(set.items()), Some(*count), "{set}");
            assert_eq!(map[set], *count);
        }
        assert_eq!(index.get(&[3]), None);
        assert_eq!(index.get(&[1, 4]), None);
        assert_eq!(index.get(&[]), None);
    }
}
