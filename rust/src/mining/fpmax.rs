//! FP-max (Grahne & Zhu 2003): maximal frequent itemsets.
//!
//! The paper's Step 1 uses FP-max "because it usually produces a smaller
//! output volume" — the Trie of Rules is then built from the maximal
//! sequences. This implementation mines with FP-growth recursion and keeps
//! an MFI (maximal-frequent-itemset) store with subsumption checking, the
//! essential structure of the original algorithm.

use std::collections::HashMap;

use crate::data::transaction::TransactionDb;
use crate::data::vocab::ItemId;
use crate::mining::counts::{min_count, ItemOrder};
use crate::mining::fpgrowth::fpgrowth;
use crate::mining::itemset::{FrequentItemsets, Itemset};

/// MFI store: maximal sets found so far, bucketed by an item they contain
/// for fast subsumption probes.
#[derive(Debug, Default)]
struct MfiStore {
    sets: Vec<(Itemset, u64)>,
    /// item -> indices of sets containing it (probe the rarest bucket).
    by_item: HashMap<ItemId, Vec<usize>>,
}

impl MfiStore {
    /// True iff `cand` is a subset of an already-stored maximal set.
    fn subsumed(&self, cand: &Itemset) -> bool {
        // Probe via the smallest bucket among cand's items.
        let bucket = cand
            .items()
            .iter()
            .filter_map(|i| self.by_item.get(i))
            .min_by_key(|b| b.len());
        match bucket {
            None => false,
            Some(b) => b.iter().any(|&idx| cand.is_subset_of(&self.sets[idx].0)),
        }
    }

    /// Insert a new maximal set, evicting any stored strict subsets.
    fn insert(&mut self, set: Itemset, count: u64) {
        if self.subsumed(&set) {
            return;
        }
        // Evict strict subsets of the new set.
        let mut keep = Vec::with_capacity(self.sets.len() + 1);
        let old = std::mem::take(&mut self.sets);
        for (s, c) in old {
            if !s.is_subset_of(&set) {
                keep.push((s, c));
            }
        }
        keep.push((set, count));
        self.sets = keep;
        self.reindex();
    }

    fn reindex(&mut self) {
        self.by_item.clear();
        for (idx, (s, _)) in self.sets.iter().enumerate() {
            for &i in s.items() {
                self.by_item.entry(i).or_default().push(idx);
            }
        }
    }
}

/// Mine maximal frequent itemsets at relative threshold `minsup`.
pub fn fpmax(db: &TransactionDb, minsup: f64) -> FrequentItemsets {
    // Mine all frequent itemsets (shares the FP-growth engine), then reduce
    // through the MFI store longest-first: a set is maximal iff no longer
    // set already in the store subsumes it. Longest-first insertion makes
    // each `insert` eviction-free and each `subsumed` probe exact.
    let all = fpgrowth(db, minsup);
    let n = db.num_transactions();
    let mut sets = all.sets;
    sets.sort_by_key(|(s, _)| std::cmp::Reverse(s.len()));

    let mut store = MfiStore::default();
    for (set, count) in sets {
        if !store.subsumed(&set) {
            store.insert(set, count);
        }
    }
    let mut out = FrequentItemsets {
        num_transactions: n,
        sets: store.sets,
    };
    out.canonicalize();
    out
}

/// The paper's "frequent sequences": maximal itemsets ordered by global
/// item frequency (Fig. 4(c) — the insertion input for the Trie of Rules).
pub fn frequent_sequences(db: &TransactionDb, minsup: f64) -> (ItemOrder, Vec<(Vec<ItemId>, u64)>) {
    let order = ItemOrder::new(db, min_count(minsup, db.num_transactions()));
    let max = fpmax(db, minsup);
    let seqs = max
        .sets
        .iter()
        .map(|(s, c)| (order.order_itemset(s.items()), *c))
        .collect();
    (order, seqs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::GeneratorConfig;
    use crate::data::transaction::paper_example_db;
    use crate::mining::naive::naive_maximal_itemsets;

    #[test]
    fn matches_naive_on_paper_example() {
        let db = paper_example_db();
        for minsup in [0.2, 0.3, 0.4, 0.6] {
            let got = fpmax(&db, minsup);
            let want = naive_maximal_itemsets(&db, minsup);
            assert_eq!(got.sets, want.sets, "minsup={minsup}");
        }
    }

    #[test]
    fn matches_naive_on_synthetic() {
        for seed in [4, 5, 6] {
            let db = GeneratorConfig::tiny(seed).generate();
            let got = fpmax(&db, 0.08);
            let want = naive_maximal_itemsets(&db, 0.08);
            assert_eq!(got.sets, want.sets, "seed={seed}");
        }
    }

    #[test]
    fn paper_fig4c_sequences() {
        // Step 1 output: (f,c,a,m,p), (f,b), (c,b) — frequency-ordered,
        // over the Fig-4(b)-filtered transactions (see paper_example_db_
        // fig4_filtered for why the example is two-tiered).
        let db = crate::data::transaction::paper_example_db_fig4_filtered();
        let (_, seqs) = frequent_sequences(&db, 0.3);
        let as_names: Vec<Vec<&str>> = seqs
            .iter()
            .map(|(s, _)| s.iter().map(|&i| db.vocab().name(i)).collect())
            .collect();
        assert_eq!(as_names.len(), 3);
        assert!(as_names.contains(&vec!["f", "c", "a", "m", "p"]));
        assert!(as_names.contains(&vec!["f", "b"]));
        assert!(as_names.contains(&vec!["c", "b"]));
    }

    #[test]
    fn maximal_sets_are_pairwise_incomparable() {
        let db = GeneratorConfig::tiny(7).generate();
        let max = fpmax(&db, 0.05);
        for (i, (a, _)) in max.sets.iter().enumerate() {
            for (j, (b, _)) in max.sets.iter().enumerate() {
                if i != j {
                    assert!(!a.is_subset_of(b), "{a} subset of {b}");
                }
            }
        }
    }

    #[test]
    fn output_smaller_than_all_frequent() {
        // The paper's motivation for FP-max: smaller output volume.
        let db = GeneratorConfig::tiny(8).generate();
        let all = fpgrowth(&db, 0.05);
        let max = fpmax(&db, 0.05);
        assert!(max.len() <= all.len());
        assert!(max.len() < all.len() || all.len() <= 1);
    }
}
