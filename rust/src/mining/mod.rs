//! Frequent-itemset mining substrate: the canonical frequency ordering, the
//! FP-tree, and four miners (Apriori with pluggable counting backends,
//! FP-growth, FP-max, ECLAT) all validated against a brute-force oracle.

pub mod apriori;
pub mod counts;
pub mod eclat;
pub mod fpgrowth;
pub mod fpmax;
pub mod fptree;
pub mod itemset;
pub mod naive;

pub use apriori::{apriori, apriori_with, BitsetCounter, HorizontalCounter, SupportCounter};
pub use counts::{min_count, ItemOrder};
pub use eclat::eclat;
pub use fpgrowth::fpgrowth;
pub use fpmax::{fpmax, frequent_sequences};
pub use fptree::FpTree;
pub use itemset::{FrequentItemsets, Itemset};

use crate::data::transaction::TransactionDb;

/// Which mining algorithm to run (CLI / pipeline config).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MinerKind {
    Apriori,
    FpGrowth,
    /// Maximal itemsets only (the paper's Step 1 default).
    FpMax,
    Eclat,
}

impl MinerKind {
    pub fn parse(s: &str) -> Option<MinerKind> {
        match s.to_ascii_lowercase().as_str() {
            "apriori" => Some(MinerKind::Apriori),
            "fpgrowth" | "fp-growth" => Some(MinerKind::FpGrowth),
            "fpmax" | "fp-max" => Some(MinerKind::FpMax),
            "eclat" => Some(MinerKind::Eclat),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MinerKind::Apriori => "apriori",
            MinerKind::FpGrowth => "fpgrowth",
            MinerKind::FpMax => "fpmax",
            MinerKind::Eclat => "eclat",
        }
    }
}

/// Run the selected miner.
pub fn mine(db: &TransactionDb, minsup: f64, kind: MinerKind) -> FrequentItemsets {
    match kind {
        MinerKind::Apriori => apriori(db, minsup),
        MinerKind::FpGrowth => fpgrowth(db, minsup),
        MinerKind::FpMax => fpmax(db, minsup),
        MinerKind::Eclat => eclat(db, minsup),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::paper_example_db;

    #[test]
    fn kind_parsing() {
        assert_eq!(MinerKind::parse("apriori"), Some(MinerKind::Apriori));
        assert_eq!(MinerKind::parse("FP-Growth"), Some(MinerKind::FpGrowth));
        assert_eq!(MinerKind::parse("fpmax"), Some(MinerKind::FpMax));
        assert_eq!(MinerKind::parse("ECLAT"), Some(MinerKind::Eclat));
        assert_eq!(MinerKind::parse("bogus"), None);
    }

    #[test]
    fn dispatch_runs_all_miners() {
        let db = paper_example_db();
        let a = mine(&db, 0.3, MinerKind::Apriori);
        let f = mine(&db, 0.3, MinerKind::FpGrowth);
        let e = mine(&db, 0.3, MinerKind::Eclat);
        let m = mine(&db, 0.3, MinerKind::FpMax);
        assert_eq!(a.sets, f.sets);
        assert_eq!(a.sets, e.sets);
        assert!(m.len() <= a.len());
    }
}
