//! The classic FP-tree (Han et al. 2004) — the substrate under both the
//! FP-growth/FP-max miners and, re-purposed per the paper, the Trie of
//! Rules itself.
//!
//! Arena-allocated nodes (`Vec<FpNode>`, index links) with per-node sorted
//! child vectors and a header table of per-item node lists for bottom-up
//! prefix-path walks.

use std::collections::HashMap;

use crate::data::transaction::TransactionDb;
use crate::data::vocab::ItemId;
use crate::mining::counts::ItemOrder;

/// Index of a node in the tree arena.
pub type NodeIdx = u32;

/// The root sits at index 0 with a sentinel item.
pub const ROOT: NodeIdx = 0;
const ROOT_ITEM: ItemId = ItemId::MAX;

#[derive(Debug, Clone)]
pub struct FpNode {
    pub item: ItemId,
    pub count: u64,
    pub parent: NodeIdx,
    /// (item, child index), sorted by item for binary search.
    children: Vec<(ItemId, NodeIdx)>,
}

/// FP-tree over frequency-ordered transactions.
#[derive(Debug, Clone)]
pub struct FpTree {
    nodes: Vec<FpNode>,
    /// item -> all node indices carrying that item.
    header: HashMap<ItemId, Vec<NodeIdx>>,
}

impl FpTree {
    pub fn empty() -> Self {
        Self {
            nodes: vec![FpNode {
                item: ROOT_ITEM,
                count: 0,
                parent: ROOT,
                children: Vec::new(),
            }],
            header: HashMap::new(),
        }
    }

    /// Build from a database: each transaction is filtered to frequent items
    /// and sorted frequency-descending before insertion (paper Step 2).
    pub fn from_db(db: &TransactionDb, order: &ItemOrder) -> Self {
        let mut tree = Self::empty();
        for tx in db.iter() {
            let path = order.order_transaction(tx);
            if !path.is_empty() {
                tree.insert(&path, 1);
            }
        }
        tree
    }

    /// Insert one frequency-ordered path with a count (overlaying shared
    /// prefixes — the compression the paper's Fig. 5 walks through).
    pub fn insert(&mut self, path: &[ItemId], count: u64) {
        let mut cur = ROOT;
        for &item in path {
            cur = match self.child(cur, item) {
                Some(c) => {
                    self.nodes[c as usize].count += count;
                    c
                }
                None => {
                    let idx = self.nodes.len() as NodeIdx;
                    self.nodes.push(FpNode {
                        item,
                        count,
                        parent: cur,
                        children: Vec::new(),
                    });
                    let pos = self.nodes[cur as usize]
                        .children
                        .binary_search_by_key(&item, |&(i, _)| i)
                        .unwrap_err();
                    self.nodes[cur as usize].children.insert(pos, (item, idx));
                    self.header.entry(item).or_default().push(idx);
                    idx
                }
            };
        }
    }

    /// Child of `node` carrying `item`, if present.
    pub fn child(&self, node: NodeIdx, item: ItemId) -> Option<NodeIdx> {
        self.nodes[node as usize]
            .children
            .binary_search_by_key(&item, |&(i, _)| i)
            .ok()
            .map(|pos| self.nodes[node as usize].children[pos].1)
    }

    pub fn node(&self, idx: NodeIdx) -> &FpNode {
        &self.nodes[idx as usize]
    }

    pub fn children(&self, idx: NodeIdx) -> &[(ItemId, NodeIdx)] {
        &self.nodes[idx as usize].children
    }

    /// Number of nodes including the root.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.len() == 1
    }

    /// Items present in the tree.
    pub fn items(&self) -> impl Iterator<Item = ItemId> + '_ {
        self.header.keys().copied()
    }

    /// All nodes carrying `item` (header-table list).
    pub fn item_nodes(&self, item: ItemId) -> &[NodeIdx] {
        self.header.get(&item).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Total count attached to `item` across the tree.
    pub fn item_count(&self, item: ItemId) -> u64 {
        self.item_nodes(item)
            .iter()
            .map(|&n| self.nodes[n as usize].count)
            .sum()
    }

    /// The path of items from `idx`'s parent up to (excluding) the root,
    /// returned root-first.
    pub fn prefix_path(&self, idx: NodeIdx) -> Vec<ItemId> {
        let mut rev = Vec::new();
        let mut cur = self.nodes[idx as usize].parent;
        while cur != ROOT {
            rev.push(self.nodes[cur as usize].item);
            cur = self.nodes[cur as usize].parent;
        }
        rev.reverse();
        rev
    }

    /// Conditional pattern base of `item`: (prefix path root-first, count)
    /// for every node carrying `item`.
    pub fn conditional_pattern_base(&self, item: ItemId) -> Vec<(Vec<ItemId>, u64)> {
        self.item_nodes(item)
            .iter()
            .map(|&n| (self.prefix_path(n), self.nodes[n as usize].count))
            .collect()
    }

    /// Build the conditional FP-tree for `item` given a count threshold:
    /// re-filter + re-order the pattern base by its local frequencies.
    pub fn conditional_tree(&self, item: ItemId, min_count: u64) -> (FpTree, Vec<(ItemId, u64)>) {
        let base = self.conditional_pattern_base(item);
        // Local item frequencies within the base.
        let mut local: HashMap<ItemId, u64> = HashMap::new();
        for (path, count) in &base {
            for &it in path {
                *local.entry(it).or_default() += count;
            }
        }
        let mut freq_items: Vec<(ItemId, u64)> = local
            .into_iter()
            .filter(|&(_, c)| c >= min_count)
            .collect();
        // Frequency-descending, id-ascending — same canonical order.
        freq_items.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let rank: HashMap<ItemId, usize> =
            freq_items.iter().enumerate().map(|(r, &(i, _))| (i, r)).collect();

        let mut tree = FpTree::empty();
        for (path, count) in &base {
            let mut p: Vec<ItemId> =
                path.iter().copied().filter(|i| rank.contains_key(i)).collect();
            p.sort_by_key(|i| rank[i]);
            if !p.is_empty() {
                tree.insert(&p, *count);
            }
        }
        (tree, freq_items)
    }

    /// True when the tree is a single chain root→leaf (FP-growth fast path).
    pub fn is_single_path(&self) -> bool {
        let mut cur = ROOT;
        loop {
            let ch = &self.nodes[cur as usize].children;
            match ch.len() {
                0 => return true,
                1 => cur = ch[0].1,
                _ => return false,
            }
        }
    }

    /// The single path (item, count) root-first; caller must check
    /// [`Self::is_single_path`].
    pub fn single_path(&self) -> Vec<(ItemId, u64)> {
        let mut out = Vec::new();
        let mut cur = ROOT;
        loop {
            let ch = &self.nodes[cur as usize].children;
            if ch.is_empty() {
                return out;
            }
            let (item, idx) = ch[0];
            out.push((item, self.nodes[idx as usize].count));
            cur = idx;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::paper_example_db;
    use crate::mining::counts::{min_count, ItemOrder};

    fn paper_tree() -> (TransactionDb, ItemOrder, FpTree) {
        let db = paper_example_db();
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let tree = FpTree::from_db(&db, &order);
        (db, order, tree)
    }

    use crate::data::transaction::TransactionDb;

    #[test]
    fn paper_example_tree_shape() {
        // Fig 5(c): root -> f(4) -> c(3) -> a(3) -> m(2) -> p(2)
        //                          f -> b(1)
        //                          c(3)->a->m->... plus c->b under root? No:
        // paths inserted: f,c,a,m,p (x2: tid1, tid5), f,c,a,b,m (tid2),
        // f,b (tid3), c,b,p (tid4).
        let (db, order, tree) = paper_tree();
        let name = |n: &str| db.vocab().get(n).unwrap();
        let f = tree.child(ROOT, name("f")).expect("f under root");
        assert_eq!(tree.node(f).count, 4);
        let c_under_f = tree.child(f, name("c")).expect("c under f");
        assert_eq!(tree.node(c_under_f).count, 3);
        let a = tree.child(c_under_f, name("a")).expect("a under c");
        assert_eq!(tree.node(a).count, 3);
        // b branch under f (tid3)
        let b_under_f = tree.child(f, name("b")).expect("b under f");
        assert_eq!(tree.node(b_under_f).count, 1);
        // c branch under root (tid4)
        let c_root = tree.child(ROOT, name("c")).expect("c under root");
        assert_eq!(tree.node(c_root).count, 1);
        // item totals = dataset frequencies (for frequent items)
        for n in ["f", "c", "a", "b", "m", "p"] {
            let id = name(n);
            assert_eq!(tree.item_count(id), order.frequency(id), "item {n}");
        }
    }

    #[test]
    fn prefix_paths() {
        let (db, _, tree) = paper_tree();
        let name = |n: &str| db.vocab().get(n).unwrap();
        let f = tree.child(ROOT, name("f")).unwrap();
        let c = tree.child(f, name("c")).unwrap();
        let a = tree.child(c, name("a")).unwrap();
        let path = tree.prefix_path(a);
        let names: Vec<&str> = path.iter().map(|&i| db.vocab().name(i)).collect();
        assert_eq!(names, vec!["f", "c"]);
        assert!(tree.prefix_path(f).is_empty());
    }

    #[test]
    fn conditional_pattern_base_of_m() {
        // Our canonical order breaks frequency ties by ascending id, giving
        // f,c,a,m,p,b (the paper's Fig. 5 picked b before m; either total
        // order is valid and ours is deterministic). Under it, all three
        // m-transactions (tids 1, 2, 5) share the prefix f,c,a, so m has a
        // single node with count 3.
        let (db, _, tree) = paper_tree();
        let m = db.vocab().get("m").unwrap();
        let base = tree.conditional_pattern_base(m);
        assert_eq!(base.len(), 1);
        let names: Vec<&str> = base[0].0.iter().map(|&i| db.vocab().name(i)).collect();
        assert_eq!(names, vec!["f", "c", "a"]);
        assert_eq!(base[0].1, 3);
    }

    #[test]
    fn conditional_tree_of_m_is_single_path() {
        let (db, _, tree) = paper_tree();
        let m = db.vocab().get("m").unwrap();
        let (cond, freq) = tree.conditional_tree(m, 2);
        // local frequent items: f:3, c:3, a:3 -> single path f-c-a
        assert!(cond.is_single_path());
        let items: Vec<ItemId> = freq.iter().map(|&(i, _)| i).collect();
        let names: std::collections::HashSet<&str> =
            items.iter().map(|&i| db.vocab().name(i)).collect();
        assert_eq!(names, ["f", "c", "a"].into_iter().collect());
        let path = cond.single_path();
        assert_eq!(path.len(), 3);
        assert!(path.iter().all(|&(_, c)| c == 3));
    }

    #[test]
    fn insert_overlays_shared_prefix() {
        let mut t = FpTree::empty();
        t.insert(&[1, 2, 3], 1);
        t.insert(&[1, 2, 4], 2);
        // nodes: root, 1, 2, 3, 4
        assert_eq!(t.len(), 5);
        let n1 = t.child(ROOT, 1).unwrap();
        assert_eq!(t.node(n1).count, 3);
        let n2 = t.child(n1, 2).unwrap();
        assert_eq!(t.node(n2).count, 3);
        assert_eq!(t.item_nodes(2).len(), 1);
    }

    #[test]
    fn empty_tree() {
        let t = FpTree::empty();
        assert!(t.is_empty());
        assert!(t.is_single_path());
        assert!(t.single_path().is_empty());
        assert_eq!(t.item_count(3), 0);
    }
}
