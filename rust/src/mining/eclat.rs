//! ECLAT (Zaki et al. 1997): vertical frequent-itemset mining.
//!
//! Transactions are transposed into per-item tid-bitsets; itemset support is
//! bitset-intersection cardinality, and the search is a DFS over the prefix
//! lattice. Included both as the background §2 comparator and as the
//! machinery behind the fast rust-native support counter used by Apriori.

use crate::data::transaction::TransactionDb;
use crate::data::vocab::ItemId;
use crate::mining::counts::min_count;
use crate::mining::itemset::{FrequentItemsets, Itemset};
use crate::util::bitset::Bitset;

/// Mine all frequent itemsets at relative threshold `minsup`.
pub fn eclat(db: &TransactionDb, minsup: f64) -> FrequentItemsets {
    let n = db.num_transactions();
    let mc = min_count(minsup, n);
    let cols = db.vertical();

    // Frequent single items, ascending id (prefix order).
    let freq_items: Vec<(ItemId, &Bitset)> = (0..cols.len() as ItemId)
        .filter(|&i| cols[i as usize].count() as u64 >= mc)
        .map(|i| (i, &cols[i as usize]))
        .collect();

    let mut out = FrequentItemsets {
        num_transactions: n,
        sets: freq_items
            .iter()
            .map(|&(i, b)| (Itemset::new(vec![i]), b.count() as u64))
            .collect(),
    };

    // DFS with prefix extension by larger item ids.
    let mut prefix: Vec<ItemId> = Vec::new();
    for (pos, &(item, tids)) in freq_items.iter().enumerate() {
        prefix.push(item);
        extend(&freq_items, pos, tids, mc, &mut prefix, &mut out);
        prefix.pop();
    }
    out.canonicalize();
    out
}

fn extend(
    items: &[(ItemId, &Bitset)],
    pos: usize,
    prefix_tids: &Bitset,
    mc: u64,
    prefix: &mut Vec<ItemId>,
    out: &mut FrequentItemsets,
) {
    for (next_pos, &(item, tids)) in items.iter().enumerate().skip(pos + 1) {
        // Candidate support without materializing: cheap reject.
        let count = prefix_tids.and_count(tids) as u64;
        if count < mc {
            continue;
        }
        prefix.push(item);
        out.sets
            .push((Itemset::from_sorted(prefix.clone()), count));
        let merged = prefix_tids.and(tids);
        extend(items, next_pos, &merged, mc, prefix, out);
        prefix.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::GeneratorConfig;
    use crate::data::transaction::paper_example_db;
    use crate::mining::fpgrowth::fpgrowth;
    use crate::mining::naive::naive_frequent_itemsets;

    #[test]
    fn matches_naive_on_paper_example() {
        let db = paper_example_db();
        for minsup in [0.2, 0.3, 0.4, 0.6] {
            let got = eclat(&db, minsup);
            let want = naive_frequent_itemsets(&db, minsup);
            assert_eq!(got.sets, want.sets, "minsup={minsup}");
        }
    }

    #[test]
    fn agrees_with_fpgrowth_on_synthetic() {
        // Cross-validation of two independent implementations.
        for seed in [10, 11, 12] {
            let db = GeneratorConfig::tiny(seed).generate();
            let a = eclat(&db, 0.06);
            let b = fpgrowth(&db, 0.06);
            assert_eq!(a.sets, b.sets, "seed={seed}");
        }
    }

    #[test]
    fn empty_at_impossible_support() {
        let db = paper_example_db();
        let fi = eclat(&db, 1.0);
        assert!(fi.sets.is_empty()); // no item appears in all 5 transactions
    }
}
