//! FP-growth (Han, Pei, Yin, Mao 2004): frequent-itemset mining without
//! candidate generation, recursing over conditional FP-trees.

use crate::data::transaction::TransactionDb;
use crate::data::vocab::ItemId;
use crate::mining::counts::{min_count, ItemOrder};
use crate::mining::fptree::FpTree;
use crate::mining::itemset::{FrequentItemsets, Itemset};

/// Mine all frequent itemsets at relative threshold `minsup`.
pub fn fpgrowth(db: &TransactionDb, minsup: f64) -> FrequentItemsets {
    let n = db.num_transactions();
    let mc = min_count(minsup, n);
    let order = ItemOrder::new(db, mc);
    let tree = FpTree::from_db(db, &order);

    let mut out = FrequentItemsets {
        num_transactions: n,
        sets: Vec::new(),
    };
    // 1-itemsets straight from the global frequencies.
    for &item in order.frequent_items() {
        out.sets
            .push((Itemset::new(vec![item]), order.frequency(item)));
    }
    let mut suffix = Vec::new();
    grow(&tree, mc, &mut suffix, &order, &mut out);
    out.canonicalize();
    out
}

/// Recursive growth over conditional trees. `suffix` is the current
/// conditional pattern (items already fixed).
fn grow(
    tree: &FpTree,
    mc: u64,
    suffix: &mut Vec<ItemId>,
    order: &ItemOrder,
    out: &mut FrequentItemsets,
) {
    if tree.is_empty() {
        return;
    }
    if tree.is_single_path() {
        // Single-path shortcut: every sub-combination of the path, with the
        // count of its deepest element.
        let path = tree.single_path();
        emit_path_combinations(&path, suffix, mc, out);
        return;
    }
    // General case: one conditional tree per item in this tree.
    let mut items: Vec<ItemId> = tree.items().collect();
    // Process in a deterministic order (rank descending = least frequent
    // first, the classic bottom-up header order).
    items.sort_by_key(|&i| std::cmp::Reverse(order.rank(i).unwrap_or(u32::MAX)));
    for item in items {
        let count = tree.item_count(item);
        if count < mc {
            continue;
        }
        suffix.push(item);
        if suffix.len() > 1 {
            // The 1-item case is emitted by the caller from global counts.
            let mut items_vec = suffix.clone();
            items_vec.sort_unstable();
            out.sets.push((Itemset::from_sorted(dedup(items_vec)), count));
        }
        let (cond, _) = tree.conditional_tree(item, mc);
        grow(&cond, mc, suffix, order, out);
        suffix.pop();
    }
}

/// Emit every non-empty combination of `path` items appended to `suffix`.
/// The support of a combination is the count of its deepest (last) element.
fn emit_path_combinations(
    path: &[(ItemId, u64)],
    suffix: &[ItemId],
    mc: u64,
    out: &mut FrequentItemsets,
) {
    let n = path.len();
    assert!(n <= 40, "single path too long for mask enumeration");
    for mask in 1u64..(1 << n) {
        let mut count = u64::MAX;
        let mut items: Vec<ItemId> = suffix.to_vec();
        for (b, &(item, c)) in path.iter().enumerate() {
            if mask >> b & 1 == 1 {
                items.push(item);
                count = count.min(c);
            }
        }
        if count >= mc && !suffix.is_empty() {
            items.sort_unstable();
            out.sets.push((Itemset::from_sorted(dedup(items)), count));
        } else if count >= mc && suffix.is_empty() && mask.count_ones() > 1 {
            items.sort_unstable();
            out.sets.push((Itemset::from_sorted(dedup(items)), count));
        }
    }
}

fn dedup(mut v: Vec<ItemId>) -> Vec<ItemId> {
    v.dedup();
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::paper_example_db;
    use crate::mining::naive::naive_frequent_itemsets;

    #[test]
    fn matches_naive_on_paper_example() {
        let db = paper_example_db();
        for minsup in [0.2, 0.3, 0.4, 0.6] {
            let mut got = fpgrowth(&db, minsup);
            let mut want = naive_frequent_itemsets(&db, minsup);
            got.canonicalize();
            want.canonicalize();
            assert_eq!(got.sets, want.sets, "minsup={minsup}");
        }
    }

    #[test]
    fn matches_naive_on_synthetic() {
        use crate::data::generator::GeneratorConfig;
        for seed in [1, 2, 3] {
            let db = GeneratorConfig::tiny(seed).generate();
            let mut got = fpgrowth(&db, 0.08);
            let mut want = naive_frequent_itemsets(&db, 0.08);
            got.canonicalize();
            want.canonicalize();
            assert_eq!(got.sets.len(), want.sets.len(), "seed={seed}");
            assert_eq!(got.sets, want.sets, "seed={seed}");
        }
    }

    #[test]
    fn supports_are_true_counts() {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        for (set, count) in &fi.sets {
            let truth = db
                .iter()
                .filter(|tx| set.items().iter().all(|i| tx.contains(i)))
                .count() as u64;
            assert_eq!(*count, truth, "itemset {set}");
        }
    }

    #[test]
    fn high_minsup_yields_singletons_only() {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.8); // only count >= 4: f, c
        assert_eq!(fi.sets.len(), 2);
        assert!(fi.sets.iter().all(|(s, _)| s.len() == 1));
    }

    #[test]
    fn no_duplicate_itemsets() {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.2);
        let uniq: std::collections::HashSet<_> = fi.sets.iter().map(|(s, _)| s.clone()).collect();
        assert_eq!(uniq.len(), fi.sets.len());
    }
}
