//! FP-growth (Han, Pei, Yin, Mao 2004): frequent-itemset mining without
//! candidate generation, recursing over conditional FP-trees.
//!
//! Two entry points share one recursion: [`fpgrowth`] runs the classic
//! sequential bottom-up loop; [`fpgrowth_parallel`] shards that loop's
//! first level — one conditional FP-tree per first-level item is fully
//! independent work — across a [`WorkerPool`], each worker emitting into a
//! private buffer merged back in deterministic rank order. Both paths end
//! with the same `canonicalize()`, so their outputs are byte-identical at
//! any thread count (enforced by `rust/tests/build_parity.rs`).

use std::sync::Mutex;

use crate::data::transaction::TransactionDb;
use crate::data::vocab::ItemId;
use crate::mining::counts::{min_count, ItemOrder};
use crate::mining::fptree::FpTree;
use crate::mining::itemset::{FrequentItemsets, Itemset};
use crate::query::parallel::WorkerPool;

/// Longest single path the mask-enumeration shortcut handles: `1u64 <<
/// path.len()` masks must fit a u64 with headroom. Longer paths fall back
/// to the general conditional-tree recursion, which streams combinations
/// (and prunes sub-threshold branches) instead of aborting the process.
const MASK_PATH_LIMIT: usize = 40;

/// Mine all frequent itemsets at relative threshold `minsup`.
pub fn fpgrowth(db: &TransactionDb, minsup: f64) -> FrequentItemsets {
    let n = db.num_transactions();
    let mc = min_count(minsup, n);
    let order = ItemOrder::new(db, mc);
    let tree = FpTree::from_db(db, &order);

    let mut out = seed_singletons(n, &order);
    let mut suffix = Vec::new();
    grow(&tree, mc, &mut suffix, &order, &mut out);
    out.canonicalize();
    out
}

/// [`fpgrowth`] with the bottom-up header loop sharded across `pool`.
///
/// The global FP-tree is built once and shared read-only; each first-level
/// item (in the canonical bottom-up order, rank descending) becomes one
/// dynamically-claimed task whose worker builds that item's conditional
/// tree and runs the ordinary [`grow`] recursion into a private buffer.
/// Partial buffers are concatenated in task (rank) order — the exact
/// sequence the sequential loop would have produced — then canonicalized,
/// so the result is byte-identical to [`fpgrowth`]'s.
pub fn fpgrowth_parallel(db: &TransactionDb, minsup: f64, pool: &WorkerPool) -> FrequentItemsets {
    let n = db.num_transactions();
    let mc = min_count(minsup, n);
    let order = ItemOrder::new(db, mc);
    let tree = FpTree::from_db(db, &order);

    let mut out = seed_singletons(n, &order);
    // No helpers, or the whole tree is one path (the shortcut handles it
    // in microseconds): run the sequential recursion — same code path the
    // sequential entry takes, so parity is trivial.
    if pool.helpers() == 0 || tree.is_single_path() {
        let mut suffix = Vec::new();
        grow(&tree, mc, &mut suffix, &order, &mut out);
        out.canonicalize();
        return out;
    }

    // One task per first-level item, in the sequential loop's order.
    let mut items: Vec<ItemId> = tree.items().collect();
    items.sort_by_key(|&i| std::cmp::Reverse(order.rank(i).unwrap_or(u32::MAX)));
    let slots: Vec<Mutex<Option<Vec<(Itemset, u64)>>>> =
        (0..items.len()).map(|_| Mutex::new(None)).collect();
    pool.run(items.len(), |t| {
        let item = items[t];
        let mut local = FrequentItemsets {
            num_transactions: n,
            sets: Vec::new(),
        };
        // Mirror of one iteration of the general case in `grow` at the
        // top level: the 1-itemset is already seeded from global counts,
        // so only the conditional recursion emits here.
        let count = tree.item_count(item);
        if count >= mc {
            let mut suffix = vec![item];
            let (cond, _) = tree.conditional_tree(item, mc);
            grow(&cond, mc, &mut suffix, &order, &mut local);
        }
        *slots[t].lock().unwrap() = Some(local.sets);
    });
    for slot in slots {
        let sets = slot
            .into_inner()
            .unwrap()
            .expect("every mining shard fills its slot");
        out.sets.extend(sets);
    }
    out.canonicalize();
    out
}

/// The shared mining preamble: 1-itemsets straight from global frequencies.
fn seed_singletons(num_transactions: usize, order: &ItemOrder) -> FrequentItemsets {
    let mut out = FrequentItemsets {
        num_transactions,
        sets: Vec::with_capacity(order.num_frequent()),
    };
    for &item in order.frequent_items() {
        out.sets
            .push((Itemset::new(vec![item]), order.frequency(item)));
    }
    out
}

/// Recursive growth over conditional trees. `suffix` is the current
/// conditional pattern (items already fixed).
fn grow(
    tree: &FpTree,
    mc: u64,
    suffix: &mut Vec<ItemId>,
    order: &ItemOrder,
    out: &mut FrequentItemsets,
) {
    if tree.is_empty() {
        return;
    }
    if tree.is_single_path() {
        // Single-path shortcut: every sub-combination of the path, with the
        // count of its deepest element. Paths beyond the mask limit fall
        // through to the general recursion instead of aborting.
        let path = tree.single_path();
        if path.len() <= MASK_PATH_LIMIT {
            emit_path_combinations(&path, suffix, mc, out);
            return;
        }
    }
    // General case: one conditional tree per item in this tree.
    let mut items: Vec<ItemId> = tree.items().collect();
    // Process in a deterministic order (rank descending = least frequent
    // first, the classic bottom-up header order).
    items.sort_by_key(|&i| std::cmp::Reverse(order.rank(i).unwrap_or(u32::MAX)));
    for item in items {
        let count = tree.item_count(item);
        if count < mc {
            continue;
        }
        suffix.push(item);
        if suffix.len() > 1 {
            // The 1-item case is emitted by the caller from global counts.
            let mut items_vec = suffix.clone();
            items_vec.sort_unstable();
            out.sets.push((Itemset::from_sorted(dedup(items_vec)), count));
        }
        let (cond, _) = tree.conditional_tree(item, mc);
        grow(&cond, mc, suffix, order, out);
        suffix.pop();
    }
}

/// Emit every non-empty combination of `path` items appended to `suffix`.
/// The support of a combination is the count of its deepest (last) element.
/// Combinations are assembled in one reusable scratch buffer truncated per
/// mask; the only allocation is the sorted copy for each *emitted* itemset.
fn emit_path_combinations(
    path: &[(ItemId, u64)],
    suffix: &[ItemId],
    mc: u64,
    out: &mut FrequentItemsets,
) {
    let n = path.len();
    debug_assert!(n <= MASK_PATH_LIMIT, "caller gates mask enumeration length");
    let mut scratch: Vec<ItemId> = Vec::with_capacity(suffix.len() + n);
    scratch.extend_from_slice(suffix);
    for mask in 1u64..(1 << n) {
        scratch.truncate(suffix.len());
        let mut count = u64::MAX;
        for (b, &(item, c)) in path.iter().enumerate() {
            if mask >> b & 1 == 1 {
                scratch.push(item);
                count = count.min(c);
            }
        }
        // With an empty suffix, single-item masks duplicate the caller's
        // global 1-itemset emission — skip those.
        if count >= mc && (!suffix.is_empty() || mask.count_ones() > 1) {
            let mut items = scratch.clone();
            items.sort_unstable();
            out.sets.push((Itemset::from_sorted(dedup(items)), count));
        }
    }
}

fn dedup(mut v: Vec<ItemId>) -> Vec<ItemId> {
    v.dedup();
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::paper_example_db;
    use crate::mining::naive::naive_frequent_itemsets;

    #[test]
    fn matches_naive_on_paper_example() {
        let db = paper_example_db();
        for minsup in [0.2, 0.3, 0.4, 0.6] {
            let mut got = fpgrowth(&db, minsup);
            let mut want = naive_frequent_itemsets(&db, minsup);
            got.canonicalize();
            want.canonicalize();
            assert_eq!(got.sets, want.sets, "minsup={minsup}");
        }
    }

    #[test]
    fn matches_naive_on_synthetic() {
        use crate::data::generator::GeneratorConfig;
        for seed in [1, 2, 3] {
            let db = GeneratorConfig::tiny(seed).generate();
            let mut got = fpgrowth(&db, 0.08);
            let mut want = naive_frequent_itemsets(&db, 0.08);
            got.canonicalize();
            want.canonicalize();
            assert_eq!(got.sets.len(), want.sets.len(), "seed={seed}");
            assert_eq!(got.sets, want.sets, "seed={seed}");
        }
    }

    #[test]
    fn supports_are_true_counts() {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        for (set, count) in &fi.sets {
            let truth = db
                .iter()
                .filter(|tx| set.items().iter().all(|i| tx.contains(i)))
                .count() as u64;
            assert_eq!(*count, truth, "itemset {set}");
        }
    }

    #[test]
    fn high_minsup_yields_singletons_only() {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.8); // only count >= 4: f, c
        assert_eq!(fi.sets.len(), 2);
        assert!(fi.sets.iter().all(|(s, _)| s.len() == 1));
    }

    #[test]
    fn no_duplicate_itemsets() {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.2);
        let uniq: std::collections::HashSet<_> = fi.sets.iter().map(|(s, _)| s.clone()).collect();
        assert_eq!(uniq.len(), fi.sets.len());
    }

    #[test]
    fn parallel_matches_sequential_on_paper_example() {
        let db = paper_example_db();
        for helpers in [0usize, 1, 3] {
            let pool = WorkerPool::new(helpers);
            for minsup in [0.2, 0.3, 0.6] {
                let seq = fpgrowth(&db, minsup);
                let par = fpgrowth_parallel(&db, minsup, &pool);
                assert_eq!(seq.sets, par.sets, "helpers={helpers} minsup={minsup}");
                assert_eq!(seq.num_transactions, par.num_transactions);
            }
        }
    }

    #[test]
    fn long_sparse_single_path_falls_back_without_abort() {
        // A single path longer than MASK_PATH_LIMIT used to abort the
        // process. The fallback recursion must handle it — cheaply, when
        // the threshold prunes the deep low-count tail.
        let mut tree = FpTree::empty();
        let long_path: Vec<ItemId> = (0..(MASK_PATH_LIMIT as ItemId + 3)).collect();
        tree.insert(&long_path, 1);
        tree.insert(&[0], 9); // only item 0 clears the threshold below
        assert!(tree.is_single_path());
        assert!(tree.single_path().len() > MASK_PATH_LIMIT);
        let order = ItemOrder::from_frequencies(
            (0..long_path.len() as ItemId)
                .map(|i| if i == 0 { 10 } else { 1 })
                .collect(),
            1,
        );
        let mut out = FrequentItemsets {
            num_transactions: 10,
            sets: Vec::new(),
        };
        let mut suffix = Vec::new();
        grow(&tree, 5, &mut suffix, &order, &mut out);
        // Item 0 (count 10) survives; it is a 1-itemset, which `grow`
        // leaves to the caller — so nothing is emitted, and nothing panics.
        assert!(out.sets.is_empty(), "{:?}", out.sets);
    }

    #[test]
    fn emit_combinations_reuses_scratch_and_matches_spec() {
        // 3-item path: 7 masks; with a non-empty suffix every one emits.
        let path = [(5 as ItemId, 4u64), (7, 3), (9, 2)];
        let mut out = FrequentItemsets {
            num_transactions: 10,
            sets: Vec::new(),
        };
        emit_path_combinations(&path, &[2], 1, &mut out);
        assert_eq!(out.sets.len(), 7);
        // Deepest-element counts: {2,5}=4, {2,7}=3, {2,5,7}=3, {2,9}=2 ...
        let get = |items: &[ItemId]| {
            out.sets
                .iter()
                .find(|(s, _)| s.items() == items)
                .map(|&(_, c)| c)
                .unwrap()
        };
        assert_eq!(get(&[2, 5]), 4);
        assert_eq!(get(&[2, 5, 7]), 3);
        assert_eq!(get(&[2, 5, 7, 9]), 2);
    }
}
