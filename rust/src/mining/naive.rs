//! Brute-force reference miner — the test oracle all four production miners
//! are checked against. Exponential; only for small test databases.

use std::collections::HashMap;

use crate::data::transaction::TransactionDb;
use crate::data::vocab::ItemId;
use crate::mining::counts::min_count;
use crate::mining::itemset::{FrequentItemsets, Itemset};

/// Enumerate all frequent itemsets by breadth-first extension with exact
/// per-transaction counting. O(2^frequent-items) worst case.
pub fn naive_frequent_itemsets(db: &TransactionDb, minsup: f64) -> FrequentItemsets {
    let n = db.num_transactions();
    let mc = min_count(minsup, n);

    // Level 1.
    let freqs = db.item_frequencies();
    let mut level: Vec<Itemset> = (0..freqs.len() as ItemId)
        .filter(|&i| freqs[i as usize] >= mc)
        .map(|i| Itemset::new(vec![i]))
        .collect();
    let mut out = FrequentItemsets {
        num_transactions: n,
        sets: level
            .iter()
            .map(|s| (s.clone(), freqs[s.items()[0] as usize]))
            .collect(),
    };
    let frequent_items: Vec<ItemId> = level.iter().map(|s| s.items()[0]).collect();

    // Extend level by level.
    while !level.is_empty() {
        let mut counts: HashMap<Itemset, u64> = HashMap::new();
        let mut next: Vec<Itemset> = Vec::new();
        for set in &level {
            let last = *set.items().last().unwrap();
            for &it in frequent_items.iter().filter(|&&i| i > last) {
                let mut items = set.items().to_vec();
                items.push(it);
                next.push(Itemset::from_sorted(items));
            }
        }
        for tx in db.iter() {
            for cand in &next {
                if cand.items().iter().all(|i| tx.contains(i)) {
                    *counts.entry(cand.clone()).or_default() += 1;
                }
            }
        }
        level = next
            .into_iter()
            .filter(|c| counts.get(c).copied().unwrap_or(0) >= mc)
            .collect();
        for set in &level {
            out.sets.push((set.clone(), counts[set]));
        }
    }
    out.canonicalize();
    out
}

/// Reference maximal-itemset filter: frequent sets with no frequent proper
/// superset.
pub fn naive_maximal_itemsets(db: &TransactionDb, minsup: f64) -> FrequentItemsets {
    let all = naive_frequent_itemsets(db, minsup);
    let maximal: Vec<(Itemset, u64)> = all
        .sets
        .iter()
        .filter(|(s, _)| {
            !all.sets
                .iter()
                .any(|(t, _)| t.len() > s.len() && s.is_subset_of(t))
        })
        .cloned()
        .collect();
    let mut out = FrequentItemsets {
        num_transactions: all.num_transactions,
        sets: maximal,
    };
    out.canonicalize();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::paper_example_db;

    #[test]
    fn paper_example_maximal_sequences() {
        // Paper Fig 4(c): FP-max at minsup 0.3 over the Fig-4(b)-filtered
        // transactions yields exactly (f,c,a,m,p), (f,b), (c,b).
        let db = crate::data::transaction::paper_example_db_fig4_filtered();
        let max = naive_maximal_itemsets(&db, 0.3);
        assert_eq!(max.sets.len(), 3);
        let as_names: Vec<(Vec<&str>, u64)> = max
            .sets
            .iter()
            .map(|(s, c)| {
                let mut names: Vec<&str> =
                    s.items().iter().map(|&i| db.vocab().name(i)).collect();
                names.sort_unstable();
                (names, *c)
            })
            .collect();
        assert!(as_names.contains(&(vec!["b", "f"], 2)));
        assert!(as_names.contains(&(vec!["b", "c"], 2)));
        assert!(as_names.contains(&(vec!["a", "c", "f", "m", "p"], 2)));
    }

    #[test]
    fn frequent_contains_singletons() {
        // At minsup 0.3 (count >= 2) the unfiltered example has 8 frequent
        // items: f c a b m p plus l and o (each appears twice).
        let db = paper_example_db();
        let all = naive_frequent_itemsets(&db, 0.3);
        let singles = all.sets.iter().filter(|(s, _)| s.len() == 1).count();
        assert_eq!(singles, 8);
    }

    #[test]
    fn downward_closure_holds() {
        let db = paper_example_db();
        let all = naive_frequent_itemsets(&db, 0.3);
        let map = all.support_map();
        for (set, count) in &all.sets {
            for sub in set.proper_subsets() {
                if sub.is_empty() {
                    continue;
                }
                let sub_count = map.get(&sub).copied().unwrap_or(0);
                assert!(
                    sub_count >= *count,
                    "anti-monotonicity violated: {sub} ({sub_count}) < {set} ({count})"
                );
            }
        }
    }
}
