//! Item-frequency ordering — the canonical order the FP-tree and the Trie
//! of Rules both sort by (paper Step 2: "items in each frequent sequence are
//! sorted according to their frequency in the original dataset").

use crate::data::transaction::TransactionDb;
use crate::data::vocab::ItemId;

/// Convert a relative minimum-support threshold into an absolute count.
///
/// `support(X) >= minsup` ⇔ `count(X) >= ceil(minsup * n)` (with an epsilon
/// so exact boundaries like 0.3 * 5 = 1.5 → 2 behave as the paper's examples
/// expect).
pub fn min_count(minsup: f64, num_transactions: usize) -> u64 {
    assert!((0.0..=1.0).contains(&minsup), "minsup must be in [0,1]");
    ((minsup * num_transactions as f64) - 1e-9).ceil().max(1.0) as u64
}

/// Frequency-descending item ranking (ties broken by ascending id, which
/// keeps the order total and deterministic).
#[derive(Debug, Clone)]
pub struct ItemOrder {
    /// rank[item] = position in frequency-descending order (0 = most
    /// frequent). Items below the support threshold get `u32::MAX`.
    rank: Vec<u32>,
    /// Items at or above the threshold, in rank order.
    frequent: Vec<ItemId>,
    freqs: Vec<u64>,
    /// The absolute count threshold the order was built with (persisted by
    /// the trie serializer).
    min_count: u64,
}

impl ItemOrder {
    /// Build from a database and an absolute count threshold.
    pub fn new(db: &TransactionDb, min_count: u64) -> Self {
        Self::from_frequencies(db.item_frequencies(), min_count)
    }

    /// Build from a merged frequency vector (sharded pipeline path).
    pub fn from_frequencies(freqs: Vec<u64>, min_count: u64) -> Self {
        let mut frequent: Vec<ItemId> = (0..freqs.len() as ItemId)
            .filter(|&i| freqs[i as usize] >= min_count)
            .collect();
        frequent.sort_by(|&a, &b| {
            freqs[b as usize]
                .cmp(&freqs[a as usize])
                .then(a.cmp(&b))
        });
        let mut rank = vec![u32::MAX; freqs.len()];
        for (r, &it) in frequent.iter().enumerate() {
            rank[it as usize] = r as u32;
        }
        Self {
            rank,
            frequent,
            freqs,
            min_count,
        }
    }

    /// The absolute count threshold this order was built with.
    pub fn min_count_used(&self) -> u64 {
        self.min_count
    }

    /// The raw frequency vector (persisted by the trie serializer).
    pub fn frequencies(&self) -> &[u64] {
        &self.freqs
    }

    pub fn num_frequent(&self) -> usize {
        self.frequent.len()
    }

    /// Frequent items in rank order (most frequent first).
    pub fn frequent_items(&self) -> &[ItemId] {
        &self.frequent
    }

    pub fn frequency(&self, item: ItemId) -> u64 {
        self.freqs[item as usize]
    }

    pub fn is_frequent(&self, item: ItemId) -> bool {
        self.rank[item as usize] != u32::MAX
    }

    /// Rank of an item; `None` if infrequent.
    pub fn rank(&self, item: ItemId) -> Option<u32> {
        match self.rank[item as usize] {
            u32::MAX => None,
            r => Some(r),
        }
    }

    /// Filter a transaction to frequent items and sort by rank
    /// (frequency-descending) — the FP-tree / trie insertion order.
    pub fn order_transaction(&self, tx: &[ItemId]) -> Vec<ItemId> {
        let mut out: Vec<ItemId> = tx.iter().copied().filter(|&i| self.is_frequent(i)).collect();
        out.sort_by_key(|&i| self.rank[i as usize]);
        out
    }

    /// Sort an itemset's items by rank (for trie paths). Panics in debug if
    /// an infrequent item sneaks in.
    pub fn order_itemset(&self, items: &[ItemId]) -> Vec<ItemId> {
        let mut out = items.to_vec();
        debug_assert!(out.iter().all(|&i| self.is_frequent(i)));
        out.sort_by_key(|&i| self.rank[i as usize]);
        out
    }

    /// Rank-sort `items` into a caller-provided buffer without allocating
    /// (hot-path variant of [`Self::order_itemset`]; EXPERIMENTS.md §Perf
    /// iteration L3-2). Returns `None` when `items` exceeds the buffer.
    #[inline]
    pub fn order_into<'a>(&self, items: &[ItemId], buf: &'a mut [ItemId]) -> Option<&'a [ItemId]> {
        if items.len() > buf.len() {
            return None;
        }
        let out = &mut buf[..items.len()];
        out.copy_from_slice(items);
        out.sort_unstable_by_key(|&i| self.rank[i as usize]);
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::paper_example_db;

    #[test]
    fn min_count_boundaries() {
        assert_eq!(min_count(0.3, 5), 2); // paper: 0.3 * 5 = 1.5 -> 2
        assert_eq!(min_count(0.4, 5), 2);
        assert_eq!(min_count(0.005, 9834), 50); // 49.17 -> 50
        assert_eq!(min_count(0.0, 100), 1);
        assert_eq!(min_count(1.0, 100), 100);
    }

    #[test]
    fn paper_example_order() {
        // Fig. 4(b) keeps items with count >= 3: f(4) c(4) a(3) b(3) m(3)
        // p(3) — at count >= 2, l and o would also qualify (the paper's
        // item table uses the higher tier; see paper_example_db_fig4_filtered).
        let db = paper_example_db();
        let order = ItemOrder::new(&db, 3);
        let names: Vec<&str> = order
            .frequent_items()
            .iter()
            .map(|&i| db.vocab().name(i))
            .collect();
        assert_eq!(names.len(), 6);
        // f and c both have 4 — f was interned first (id order breaks tie).
        assert_eq!(&names[..2], &["f", "c"]);
        let tail: std::collections::HashSet<&str> = names[2..].iter().copied().collect();
        assert_eq!(tail, ["a", "b", "m", "p"].into_iter().collect());
    }

    #[test]
    fn order_transaction_filters_and_sorts() {
        let db = paper_example_db();
        let order = ItemOrder::new(&db, 2);
        // TID 1: f,a,c,d,g,i,m,p -> frequent part ordered f,c,a,m,p
        // (paper's first frequent sequence!)
        let ordered = order.order_transaction(db.transaction(0));
        let names: Vec<&str> = ordered.iter().map(|&i| db.vocab().name(i)).collect();
        assert_eq!(names, vec!["f", "c", "a", "m", "p"]);
    }

    #[test]
    fn rank_consistency() {
        let db = paper_example_db();
        let order = ItemOrder::new(&db, 2);
        for (r, &it) in order.frequent_items().iter().enumerate() {
            assert_eq!(order.rank(it), Some(r as u32));
        }
        let d = db.vocab().get("d").unwrap();
        assert_eq!(order.rank(d), None);
        assert!(!order.is_frequent(d));
    }
}
