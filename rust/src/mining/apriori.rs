//! Apriori (Agrawal & Srikant) with pluggable support-counting backends.
//!
//! The paper's evaluation mines the Groceries ruleset with Apriori; this
//! implementation is also where the three-layer architecture plugs in: the
//! level-wise counting step takes any [`SupportCounter`], and the PJRT
//! runtime provides an XLA-artifact-backed one
//! ([`crate::runtime::support_exec::XlaSupportCounter`]) that runs the L1
//! Pallas kernel. The rust-native [`BitsetCounter`] is the default and the
//! ablation baseline (DESIGN.md A2).

use std::collections::HashSet;

use crate::data::transaction::TransactionDb;
use crate::data::vocab::ItemId;
use crate::mining::counts::min_count;
use crate::mining::itemset::{FrequentItemsets, Itemset};
use crate::util::bitset::Bitset;

/// A backend that counts the absolute support of candidate itemsets.
pub trait SupportCounter {
    fn count(&mut self, candidates: &[Itemset]) -> Vec<u64>;

    /// Diagnostic label for telemetry/bench output.
    fn name(&self) -> &'static str {
        "unnamed"
    }
}

/// Vertical bitset counter: per-item tid-bitsets, intersection cardinality
/// per candidate. The fast rust-native path.
pub struct BitsetCounter {
    cols: Vec<Bitset>,
}

impl BitsetCounter {
    pub fn new(db: &TransactionDb) -> Self {
        Self {
            cols: db.vertical(),
        }
    }
}

impl SupportCounter for BitsetCounter {
    fn count(&mut self, candidates: &[Itemset]) -> Vec<u64> {
        candidates
            .iter()
            .map(|c| {
                let sets: Vec<&Bitset> =
                    c.items().iter().map(|&i| &self.cols[i as usize]).collect();
                Bitset::multi_and_count(&sets) as u64
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "bitset"
    }
}

/// Horizontal scan counter: re-reads every transaction per level, checking
/// candidate subset membership. The classic textbook formulation; slowest,
/// kept as a baseline and oracle.
pub struct HorizontalCounter<'a> {
    db: &'a TransactionDb,
}

impl<'a> HorizontalCounter<'a> {
    pub fn new(db: &'a TransactionDb) -> Self {
        Self { db }
    }
}

impl SupportCounter for HorizontalCounter<'_> {
    fn count(&mut self, candidates: &[Itemset]) -> Vec<u64> {
        let mut counts = vec![0u64; candidates.len()];
        for tx in self.db.iter() {
            for (k, cand) in candidates.iter().enumerate() {
                if crate::mining::itemset::sorted_subset(cand.items(), tx) {
                    counts[k] += 1;
                }
            }
        }
        counts
    }

    fn name(&self) -> &'static str {
        "horizontal"
    }
}

/// Mine all frequent itemsets with the default bitset backend.
pub fn apriori(db: &TransactionDb, minsup: f64) -> FrequentItemsets {
    let mut counter = BitsetCounter::new(db);
    apriori_with(db, minsup, &mut counter)
}

/// Mine all frequent itemsets with a caller-supplied counting backend.
pub fn apriori_with(
    db: &TransactionDb,
    minsup: f64,
    counter: &mut dyn SupportCounter,
) -> FrequentItemsets {
    let n = db.num_transactions();
    let mc = min_count(minsup, n);

    // L1 from exact item frequencies (cheap, no backend needed).
    let freqs = db.item_frequencies();
    let mut level: Vec<(Itemset, u64)> = (0..freqs.len() as ItemId)
        .filter(|&i| freqs[i as usize] >= mc)
        .map(|i| (Itemset::new(vec![i]), freqs[i as usize]))
        .collect();

    let mut out = FrequentItemsets {
        num_transactions: n,
        sets: level.clone(),
    };

    while !level.is_empty() {
        let candidates = generate_candidates(&level);
        if candidates.is_empty() {
            break;
        }
        let counts = counter.count(&candidates);
        debug_assert_eq!(counts.len(), candidates.len());
        level = candidates
            .into_iter()
            .zip(counts)
            .filter(|&(_, c)| c >= mc)
            .collect();
        out.sets.extend(level.iter().cloned());
    }
    out.canonicalize();
    out
}

/// Classic join + prune candidate generation: join two k-sets sharing their
/// first k-1 items, prune candidates with an infrequent k-subset.
pub fn generate_candidates(level: &[(Itemset, u64)]) -> Vec<Itemset> {
    let prev: HashSet<&Itemset> = level.iter().map(|(s, _)| s).collect();
    let mut sorted: Vec<&Itemset> = level.iter().map(|(s, _)| s).collect();
    sorted.sort();

    let mut out = Vec::new();
    for i in 0..sorted.len() {
        for j in i + 1..sorted.len() {
            let a = sorted[i].items();
            let b = sorted[j].items();
            let k = a.len();
            // Join condition: identical first k-1 items (sorted order makes
            // the joinable js contiguous — break when the prefix diverges).
            if a[..k - 1] != b[..k - 1] {
                break;
            }
            let mut items = a.to_vec();
            items.push(b[k - 1]);
            let cand = Itemset::from_sorted(items);
            // Prune: all k-subsets must be frequent.
            let all_frequent = (0..cand.len()).all(|drop| {
                let sub: Vec<ItemId> = cand
                    .items()
                    .iter()
                    .enumerate()
                    .filter(|&(idx, _)| idx != drop)
                    .map(|(_, &it)| it)
                    .collect();
                prev.contains(&Itemset::from_sorted(sub))
            });
            if all_frequent {
                out.push(cand);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::GeneratorConfig;
    use crate::data::transaction::paper_example_db;
    use crate::mining::fpgrowth::fpgrowth;
    use crate::mining::naive::naive_frequent_itemsets;

    #[test]
    fn matches_naive_on_paper_example() {
        let db = paper_example_db();
        for minsup in [0.2, 0.3, 0.4, 0.6] {
            let got = apriori(&db, minsup);
            let want = naive_frequent_itemsets(&db, minsup);
            assert_eq!(got.sets, want.sets, "minsup={minsup}");
        }
    }

    #[test]
    fn backends_agree() {
        for seed in [20, 21] {
            let db = GeneratorConfig::tiny(seed).generate();
            let with_bitset = apriori(&db, 0.06);
            let mut h = HorizontalCounter::new(&db);
            let with_horizontal = apriori_with(&db, 0.06, &mut h);
            assert_eq!(with_bitset.sets, with_horizontal.sets, "seed={seed}");
        }
    }

    #[test]
    fn agrees_with_fpgrowth() {
        for seed in [22, 23] {
            let db = GeneratorConfig::tiny(seed).generate();
            let a = apriori(&db, 0.07);
            let b = fpgrowth(&db, 0.07);
            assert_eq!(a.sets, b.sets, "seed={seed}");
        }
    }

    #[test]
    fn candidate_generation_join_prune() {
        // L2 = {1,2},{1,3},{2,3},{2,4}: joins -> {1,2,3} (kept: all subsets
        // frequent), {2,3,4} (pruned: {3,4} missing).
        let level: Vec<(Itemset, u64)> = [vec![1, 2], vec![1, 3], vec![2, 3], vec![2, 4]]
            .into_iter()
            .map(|v| (Itemset::new(v), 2))
            .collect();
        let cands = generate_candidates(&level);
        assert_eq!(cands, vec![Itemset::new(vec![1, 2, 3])]);
    }

    #[test]
    fn counter_names() {
        let db = paper_example_db();
        assert_eq!(BitsetCounter::new(&db).name(), "bitset");
        assert_eq!(HorizontalCounter::new(&db).name(), "horizontal");
    }
}
