//! Statistics for the evaluation harness: descriptive summaries, paired
//! t-tests (paper Figs. 9, 12b, 13b), histograms, and the special functions
//! (`ln_gamma`, regularized incomplete beta) that back the p-values.

pub mod descriptive;
pub mod histogram;
pub mod special;
pub mod ttest;

pub use descriptive::{mean, percentile_sorted, Summary, Welford};
pub use histogram::Histogram;
pub use ttest::PairedTTest;
