//! Fixed-bin histogram with an ASCII renderer.
//!
//! The paper presents Figs. 9, 12(b), 13(b) as histograms of paired timing
//! differences; the bench harness prints the same shape as text so the
//! "figure" is regenerated directly in the bench output.

/// Histogram over `[lo, hi)` with `bins` equal-width bins plus outlier bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    below: u64,
    above: u64,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self {
            lo,
            hi,
            counts: vec![0; bins],
            below: 0,
            above: 0,
            total: 0,
        }
    }

    /// Build a histogram spanning the sample range.
    pub fn of(xs: &[f64], bins: usize) -> Self {
        assert!(!xs.is_empty());
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let hi = if hi > lo { hi } else { lo + 1.0 };
        // widen hi slightly so the max lands in the last bin
        let mut h = Histogram::new(lo, hi + (hi - lo) * 1e-9, bins);
        for &x in xs {
            h.push(x);
        }
        h
    }

    pub fn push(&mut self, x: f64) {
        self.total += 1;
        if x < self.lo {
            self.below += 1;
        } else if x >= self.hi {
            self.above += 1;
        } else {
            let w = (self.hi - self.lo) / self.counts.len() as f64;
            let idx = ((x - self.lo) / w) as usize;
            let idx = idx.min(self.counts.len() - 1);
            self.counts[idx] += 1;
        }
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Bin center for bin `i`.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// Render as an ASCII bar chart, `width` chars for the largest bar.
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width) / max as usize);
            out.push_str(&format!("{:>12.4e} | {:<width$} {}\n", self.center(i), bar, c));
        }
        if self.below > 0 || self.above > 0 {
            out.push_str(&format!("(outliers: {} below, {} above)\n", self.below, self.above));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_capture_values() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        assert_eq!(h.counts(), &[1; 10]);
        assert_eq!(h.total(), 10);
    }

    #[test]
    fn outliers_tracked() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(-5.0);
        h.push(2.0);
        h.push(0.5);
        assert_eq!(h.total(), 3);
        assert_eq!(h.counts().iter().sum::<u64>(), 1);
        assert!(h.render(10).contains("outliers: 1 below, 1 above"));
    }

    #[test]
    fn of_spans_sample() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let h = Histogram::of(&xs, 5);
        assert_eq!(h.total(), 5);
        assert_eq!(h.counts().iter().sum::<u64>(), 5); // no outliers
    }

    #[test]
    fn render_scales_bars() {
        let mut h = Histogram::new(0.0, 2.0, 2);
        for _ in 0..10 {
            h.push(0.5);
        }
        h.push(1.5);
        let r = h.render(20);
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines[0].matches('#').count() > lines[1].matches('#').count());
    }
}
