//! Descriptive statistics over `f64` samples (Welford accumulation,
//! percentiles). Used by the bench harness and the paired-difference
//! analyses behind paper Figs. 8–13.

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator). NaN for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Summary of a sample: moments plus order statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a sample. Panics on an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std_dev: if xs.len() > 1 { w.std_dev() } else { 0.0 },
            min: sorted[0],
            p25: percentile_sorted(&sorted, 0.25),
            median: percentile_sorted(&sorted, 0.50),
            p75: percentile_sorted(&sorted, 0.75),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[sorted.len() - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, `q` in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // direct sample variance
        let m = 5.0;
        let var: f64 = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&sorted, 0.0), 1.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 4.0);
        assert!((percentile_sorted(&sorted, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.median - 50.5).abs() < 1e-9);
        assert!(s.p95 > s.p75 && s.p75 > s.p25);
    }

    #[test]
    fn single_element_summary() {
        let s = Summary::of(&[3.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        let _ = Summary::of(&[]);
    }
}
