//! Special functions needed for the Student-t p-value: log-gamma (Lanczos)
//! and the regularized incomplete beta function (Lentz continued fraction).
//!
//! Accuracy target: ~1e-10 relative over the parameter ranges the t-test
//! uses (degrees of freedom up to ~10^6) — verified against known values in
//! the tests below.

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    // Coefficients from the standard Lanczos g=7 table.
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized incomplete beta function I_x(a, b).
pub fn betainc(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "betainc requires a,b > 0");
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    // Use the symmetry that keeps the continued fraction convergent. Both
    // branches evaluate the continued fraction directly (no mutual
    // recursion: x == (a+1)/(a+b+2) with symmetric a,b would never
    // terminate otherwise).
    if x < (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(a, b, x) / a
    } else {
        1.0 - front * beta_cf(b, a, 1.0 - x) / b
    }
}

/// Continued fraction for betainc (modified Lentz).
fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 1e-15;
    const TINY: f64 = 1e-300;

    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < TINY {
        d = TINY;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // even step
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        h *= d * c;
        // odd step
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Two-sided survival function of the Student t distribution:
/// `P(|T_df| >= |t|)`.
pub fn student_t_two_sided_p(t: f64, df: f64) -> f64 {
    assert!(df > 0.0);
    if !t.is_finite() {
        return 0.0;
    }
    let x = df / (df + t * t);
    // P(|T| >= t) = I_{df/(df+t^2)}(df/2, 1/2)
    betainc(df / 2.0, 0.5, x).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Gamma(1)=1, Gamma(2)=1, Gamma(5)=24, Gamma(0.5)=sqrt(pi)
        assert!((ln_gamma(1.0)).abs() < 1e-12);
        assert!((ln_gamma(2.0)).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn betainc_boundaries_and_symmetry() {
        assert_eq!(betainc(2.0, 3.0, 0.0), 0.0);
        assert_eq!(betainc(2.0, 3.0, 1.0), 1.0);
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        for &(a, b, x) in &[(2.0, 3.0, 0.3), (0.5, 0.5, 0.7), (5.0, 1.5, 0.2)] {
            let lhs = betainc(a, b, x);
            let rhs = 1.0 - betainc(b, a, 1.0 - x);
            assert!((lhs - rhs).abs() < 1e-10, "a={a} b={b} x={x}");
        }
    }

    #[test]
    fn betainc_uniform_case() {
        // I_x(1,1) = x
        for x in [0.1, 0.25, 0.5, 0.9] {
            assert!((betainc(1.0, 1.0, x) - x).abs() < 1e-12);
        }
    }

    #[test]
    fn t_distribution_reference_values() {
        // df=10, t=2.228 is the 97.5th percentile -> two-sided p ≈ 0.05
        let p = student_t_two_sided_p(2.228, 10.0);
        assert!((p - 0.05).abs() < 1e-3, "p = {p}");
        // df=1 (Cauchy), t=1 -> two-sided p = 0.5
        let p = student_t_two_sided_p(1.0, 1.0);
        assert!((p - 0.5).abs() < 1e-10, "p = {p}");
        // huge t -> p ~ 0
        assert!(student_t_two_sided_p(50.0, 30.0) < 1e-12);
        // t = 0 -> p = 1
        assert!((student_t_two_sided_p(0.0, 5.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn t_distribution_large_df_approaches_normal() {
        // df=1e6, t=1.96 -> p ≈ 0.05
        let p = student_t_two_sided_p(1.959_964, 1e6);
        assert!((p - 0.05).abs() < 1e-4, "p = {p}");
    }
}
