//! Paired Student t-test — the significance machinery behind the paper's
//! Figs. 9, 12(b) and 13(b): "null hypothesis that the difference in times
//! between these methods is zero".

use crate::stats::descriptive::Welford;
use crate::stats::special::student_t_two_sided_p;

/// Result of a paired t-test over per-item timing differences.
#[derive(Debug, Clone, PartialEq)]
pub struct PairedTTest {
    pub n: usize,
    pub mean_diff: f64,
    pub std_diff: f64,
    pub t_statistic: f64,
    pub df: f64,
    /// Two-sided p-value for H0: mean difference == 0.
    pub p_value: f64,
}

impl PairedTTest {
    /// Paired t-test of `a` vs `b` (differences `a[i] - b[i]`).
    ///
    /// Panics if lengths differ or fewer than 2 pairs are given.
    pub fn run(a: &[f64], b: &[f64]) -> PairedTTest {
        assert_eq!(a.len(), b.len(), "paired t-test needs equal-length samples");
        assert!(a.len() >= 2, "paired t-test needs >= 2 pairs");
        let mut w = Welford::new();
        for (&x, &y) in a.iter().zip(b) {
            w.push(x - y);
        }
        let n = a.len();
        let mean = w.mean();
        let sd = w.std_dev();
        let df = (n - 1) as f64;
        let se = sd / (n as f64).sqrt();
        let t = if se == 0.0 {
            if mean == 0.0 {
                0.0
            } else {
                f64::INFINITY * mean.signum()
            }
        } else {
            mean / se
        };
        let p = if t.is_infinite() {
            0.0
        } else {
            student_t_two_sided_p(t, df)
        };
        PairedTTest {
            n,
            mean_diff: mean,
            std_diff: sd,
            t_statistic: t,
            df,
            p_value: p,
        }
    }

    /// True when H0 (zero mean difference) is rejected at `alpha`.
    pub fn rejects_null(&self, alpha: f64) -> bool {
        self.p_value < alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_samples_do_not_reject() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let t = PairedTTest::run(&a, &a);
        assert_eq!(t.mean_diff, 0.0);
        assert_eq!(t.t_statistic, 0.0);
        assert!((t.p_value - 1.0).abs() < 1e-12);
        assert!(!t.rejects_null(0.05));
    }

    #[test]
    fn clearly_shifted_samples_reject() {
        let mut rng = Rng::new(1);
        let a: Vec<f64> = (0..200).map(|_| rng.f64()).collect();
        let b: Vec<f64> = a.iter().map(|x| x + 1.0 + 0.01 * rng.f64()).collect();
        let t = PairedTTest::run(&a, &b);
        assert!(t.mean_diff < -0.9);
        assert!(t.p_value < 1e-10);
        assert!(t.rejects_null(0.05));
    }

    #[test]
    fn known_textbook_case() {
        // Hand-computed: diffs = [1,1,3,8,2,2], mean 2.8333, sd 2.6395,
        // se 1.0776 -> t = 2.6294 with df = 5; two-sided p ≈ 0.0465.
        let a = [30.0, 31.0, 34.0, 40.0, 36.0, 35.0];
        let b = [29.0, 30.0, 31.0, 32.0, 34.0, 33.0];
        let t = PairedTTest::run(&a, &b);
        assert!((t.t_statistic - 2.6294).abs() < 1e-3, "t = {}", t.t_statistic);
        assert!((t.p_value - 0.0465).abs() < 2e-3, "p = {}", t.p_value);
        assert!(t.rejects_null(0.05));
        assert!(!t.rejects_null(0.01));
    }

    #[test]
    fn noise_does_not_reject() {
        let mut rng = Rng::new(5);
        let a: Vec<f64> = (0..100).map(|_| rng.f64()).collect();
        let b: Vec<f64> = (0..100).map(|_| rng.f64()).collect();
        let t = PairedTTest::run(&a, &b);
        // Independent uniforms with equal mean: typically not significant.
        assert!(t.p_value > 0.001, "p = {}", t.p_value);
    }

    #[test]
    #[should_panic(expected = "equal-length")]
    fn mismatched_lengths_panic() {
        let _ = PairedTTest::run(&[1.0, 2.0], &[1.0]);
    }
}
