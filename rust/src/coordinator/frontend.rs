//! Nonblocking high-fanout TCP front end for the query service.
//!
//! Replaces the thread-per-connection server (kept as
//! [`super::service::serve_tcp_blocking`], the parity baseline) with one
//! acceptor plus N event-loop **shards**. Each shard owns a
//! [`Slab`](crate::coordinator::netpoll::Slab) of per-connection state
//! machines and sweeps them with *readiness-by-attempt* I/O: every socket
//! is nonblocking and a `WouldBlock` return is the "not ready" signal (the
//! vendor set has no `libc`, so there is no `poll(2)` to park on — see
//! `coordinator/netpoll.rs`). 10k+ connections therefore cost 10k+ slab
//! entries and buffers, not 10k+ OS threads.
//!
//! **Wire protocols.** A connection speaks one of two framings, negotiated
//! by its first bytes:
//!
//! * *Text* (the legacy protocol, byte-for-byte compatible with the
//!   blocking server): one `\n`-terminated request line per command, one
//!   `\n`-terminated (possibly multi-line, self-delimiting) response.
//! * *Binary* (`RQL2`): the client's first 4 bytes are the magic
//!   `b"RQL2"`; thereafter every request **and** response is a
//!   `u32`-big-endian length prefix followed by that many payload bytes.
//!   Payloads are exactly the text commands/responses, minus the line
//!   framing — so binary and text parity is structural, not coincidental.
//!   The magic cannot collide with the text protocol: no RQL verb starts
//!   with `RQL2`.
//!
//! Requests **pipeline**: a client may send many frames without waiting;
//! each connection's responses are generated strictly in request order
//! (the per-connection state machine is swept by exactly one shard).
//!
//! **Admission control.** Parsed requests claim a slot from a global
//! [`AdmissionControl`] bound (`max_pending` config key). A request that
//! finds the bound exhausted is answered `BUSY` in-order instead of
//! queueing unboundedly — the nonblocking analogue of the unbounded thread
//! growth the old server suffered under overload. Sheds are counted
//! (`tor_shed_requests_total`).
//!
//! **Robustness.** Request size is capped at [`MAX_REQUEST_BYTES`] in both
//! framings (`ERR line too long` / `ERR frame too long`, then close), and
//! an optional per-connection idle timeout (`idle_timeout_s`) evicts dead
//! clients (`tor_idle_evicted_conns_total`).

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::backpressure::{AdmissionControl, AdmissionPermit, BoundedQueue};
use super::netpoll::{IdleBackoff, Interest, Slab, Token};
use super::service::QueryEngine;
use crate::obs::registry::Gauge;

/// The engine surface the front end drives. One request line in, one
/// response string out, plus the observability touchpoints the serving
/// loops hit on shed/evict/close. Implemented by the single-node
/// [`QueryEngine`] and by the scatter-gather coordinator
/// ([`super::scatter::ScatterEngine`]), so both are served by the same
/// acceptor + shard-loop machinery and speak identical wire protocols.
pub trait RequestHandler: Send + Sync + 'static {
    /// Execute one text request (framing already stripped).
    fn execute(&self, line: &str) -> String;
    /// Gauge of currently open connections.
    fn conn_gauge(&self) -> Gauge;
    /// A request was refused with `BUSY` by admission control.
    fn note_shed(&self);
    /// A connection was evicted for idleness.
    fn note_idle_evicted(&self);
    /// Orderly-stop drain (durability flush, telemetry flush).
    fn shutdown_flush(&self);
}

impl RequestHandler for QueryEngine {
    fn execute(&self, line: &str) -> String {
        QueryEngine::execute(self, line)
    }
    fn conn_gauge(&self) -> Gauge {
        QueryEngine::conn_gauge(self)
    }
    fn note_shed(&self) {
        QueryEngine::note_shed(self)
    }
    fn note_idle_evicted(&self) {
        QueryEngine::note_idle_evicted(self)
    }
    fn shutdown_flush(&self) {
        QueryEngine::shutdown_flush(self)
    }
}

/// Hard cap on one request's payload (text line or binary frame body).
pub const MAX_REQUEST_BYTES: usize = 64 * 1024;

/// Magic prefix a client sends to negotiate the binary framing.
pub const BINARY_MAGIC: &[u8; 4] = b"RQL2";

/// Bytes pulled per `read` attempt.
const READ_CHUNK: usize = 16 * 1024;
/// Per-connection, per-sweep read budget, so one firehose client cannot
/// starve its shard's other connections.
const READ_SWEEP_MAX: usize = 256 * 1024;
/// Stop reading (but keep writing) once this many response bytes are
/// queued: a client that sends fast and reads slowly is backpressured by
/// its own socket instead of growing our buffer without bound.
const WRITE_HIGH_WATER: usize = 1 << 20;
/// After this many consecutive no-progress sweeps a connection is "cold"…
const COLD_AFTER_SWEEPS: u32 = 64;
/// …and is probed only every this-many sweeps (staggered by token), so
/// 10k idle connections cost ~1/8 of the syscalls per sweep.
const COLD_PROBE_PERIOD: u64 = 8;
/// Acceptor→shard handoff queue depth (per shard).
const ACCEPT_QUEUE_CAP: usize = 256;
/// Compact consumed buffer prefixes past this size.
const COMPACT_THRESHOLD: usize = 64 * 1024;

/// Front-end tuning knobs (config keys `service_shards`, `max_pending`,
/// `idle_timeout_s`; flags `--service-shards`, `--max-pending`,
/// `--idle-timeout-s`).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Event-loop shard count; 0 = auto ([`default_service_shards`]).
    pub shards: usize,
    /// Global bound on in-flight admitted requests (`BUSY` beyond it).
    pub max_pending: usize,
    /// Evict a connection after this much inactivity; `None` = never.
    pub idle_timeout: Option<Duration>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            shards: 0,
            max_pending: 1024,
            idle_timeout: None,
        }
    }
}

/// Auto shard count: available cores, capped — the shards only shuffle
/// bytes and parse; query execution parallelism lives in the engine's
/// worker pool, so a handful of loops drives a lot of connections.
pub fn default_service_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 4)
}

/// Which framing a connection settled on (or hasn't yet).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Mode {
    Negotiating,
    Text,
    Binary,
}

/// One step of the incremental request parser.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum Step {
    /// A complete request payload (UTF-8, framing stripped).
    Request(String),
    /// The buffer holds no complete request; read more.
    NeedMore,
    /// The current request exceeds [`MAX_REQUEST_BYTES`].
    TooLong,
    /// The current request is not valid UTF-8 (connection is dropped, as
    /// the blocking server's `lines()` did).
    BadUtf8,
}

/// Incremental, fragmentation-proof protocol state machine. Pure w.r.t.
/// I/O: it only looks at `buf[*pos..]` and advances `*pos` past each
/// consumed request, so it is directly testable on byte-split inputs.
#[derive(Debug)]
pub(crate) struct ProtoState {
    mode: Mode,
}

impl ProtoState {
    pub(crate) fn new() -> Self {
        ProtoState {
            mode: Mode::Negotiating,
        }
    }

    pub(crate) fn mode(&self) -> Mode {
        self.mode
    }

    /// Try to extract the next complete request from `buf[*pos..]`.
    /// `eof` marks that no more bytes will ever arrive (peer half-closed):
    /// a final unterminated text line is then processed — exactly what
    /// `BufRead::lines` gave the blocking server — while an incomplete
    /// binary frame is abandoned as `NeedMore` (the caller closes).
    pub(crate) fn next_request(&mut self, buf: &[u8], pos: &mut usize, eof: bool) -> Step {
        if self.mode == Mode::Negotiating {
            let avail = &buf[*pos..];
            if avail.len() >= BINARY_MAGIC.len() {
                if &avail[..BINARY_MAGIC.len()] == BINARY_MAGIC {
                    *pos += BINARY_MAGIC.len();
                    self.mode = Mode::Binary;
                } else {
                    self.mode = Mode::Text;
                }
            } else if avail.contains(&b'\n') || (eof && !avail.is_empty()) {
                // Too short to be the magic, provably a text line.
                self.mode = Mode::Text;
            } else {
                return Step::NeedMore;
            }
        }
        let avail = &buf[*pos..];
        match self.mode {
            Mode::Text => match avail.iter().position(|&b| b == b'\n') {
                // The cap must not depend on how the bytes were fragmented:
                // the blocking server's `take(MAX+1).read_until` rejects any
                // line whose pre-`\n` bytes exceed the cap, so an oversized
                // line is TooLong even when its newline is already buffered.
                Some(i) if i > MAX_REQUEST_BYTES => Step::TooLong,
                Some(i) => {
                    let mut line = &avail[..i];
                    if line.last() == Some(&b'\r') {
                        line = &line[..line.len() - 1];
                    }
                    let step = match std::str::from_utf8(line) {
                        Ok(s) => Step::Request(s.to_string()),
                        Err(_) => Step::BadUtf8,
                    };
                    *pos += i + 1;
                    step
                }
                None if avail.len() > MAX_REQUEST_BYTES => Step::TooLong,
                None if eof && !avail.is_empty() => {
                    let step = match std::str::from_utf8(avail) {
                        Ok(s) => Step::Request(s.to_string()),
                        Err(_) => Step::BadUtf8,
                    };
                    *pos = buf.len();
                    step
                }
                None => Step::NeedMore,
            },
            Mode::Binary => {
                if avail.len() < 4 {
                    return Step::NeedMore;
                }
                let n = u32::from_be_bytes([avail[0], avail[1], avail[2], avail[3]]) as usize;
                if n > MAX_REQUEST_BYTES {
                    return Step::TooLong;
                }
                if avail.len() < 4 + n {
                    return Step::NeedMore;
                }
                let step = match std::str::from_utf8(&avail[4..4 + n]) {
                    Ok(s) => Step::Request(s.to_string()),
                    Err(_) => Step::BadUtf8,
                };
                *pos += 4 + n;
                step
            }
            Mode::Negotiating => unreachable!("negotiation resolved above"),
        }
    }
}

/// Append one response to a connection's write buffer in its framing.
pub(crate) fn push_response(mode: Mode, wbuf: &mut Vec<u8>, resp: &str) {
    match mode {
        Mode::Binary => {
            wbuf.extend_from_slice(&(resp.len() as u32).to_be_bytes());
            wbuf.extend_from_slice(resp.as_bytes());
        }
        // Negotiating can only reach here for the degenerate "reply while
        // still negotiating" path, which never happens: responses are only
        // produced from parsed requests, and parsing fixes the mode.
        Mode::Text | Mode::Negotiating => {
            wbuf.extend_from_slice(resp.as_bytes());
            wbuf.push(b'\n');
        }
    }
}

/// What one connection sweep concluded.
struct Sweep {
    progress: bool,
    close: bool,
    idle_evicted: bool,
}

impl Sweep {
    fn close_now(progress: bool) -> Sweep {
        Sweep {
            progress,
            close: true,
            idle_evicted: false,
        }
    }
}

/// Per-connection state machine: nonblocking socket + incremental read
/// buffer (`rbuf[rpos..]` unparsed) + pending write buffer
/// (`wbuf[wpos..]` unsent).
struct Conn {
    stream: TcpStream,
    proto: ProtoState,
    rbuf: Vec<u8>,
    rpos: usize,
    wbuf: Vec<u8>,
    wpos: usize,
    last_active: Instant,
    /// Consecutive sweeps without progress (drives cold-probe skipping).
    idle_sweeps: u32,
    /// Peer half-closed its send side (read returned 0).
    read_closed: bool,
    /// We decided to finish: flush `wbuf`, then drop the connection.
    closing: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            proto: ProtoState::new(),
            rbuf: Vec::new(),
            rpos: 0,
            wbuf: Vec::new(),
            wpos: 0,
            last_active: Instant::now(),
            idle_sweeps: 0,
            read_closed: false,
            closing: false,
        }
    }

    fn pending_write(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Readiness set this connection currently wants probed.
    fn interest(&self) -> Interest {
        let mut interest = Interest::NONE;
        if !self.read_closed && !self.closing && self.pending_write() < WRITE_HIGH_WATER {
            interest = interest.with(Interest::READ);
        }
        if self.pending_write() > 0 {
            interest = interest.with(Interest::WRITE);
        }
        interest
    }

    /// One readiness-by-attempt sweep: read what's there, parse + execute
    /// complete requests in order, flush what fits.
    fn service<E: RequestHandler>(
        &mut self,
        engine: &E,
        admission: &AdmissionControl,
        now: Instant,
        idle_timeout: Option<Duration>,
    ) -> Sweep {
        let interest = self.interest();
        let mut progress = false;

        // ---- read phase -------------------------------------------------
        if interest.readable() {
            let mut swept = 0usize;
            loop {
                let old_len = self.rbuf.len();
                self.rbuf.resize(old_len + READ_CHUNK, 0);
                match self.stream.read(&mut self.rbuf[old_len..]) {
                    Ok(0) => {
                        self.rbuf.truncate(old_len);
                        self.read_closed = true;
                        progress = true;
                        break;
                    }
                    Ok(n) => {
                        self.rbuf.truncate(old_len + n);
                        progress = true;
                        swept += n;
                        if swept >= READ_SWEEP_MAX {
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => {
                        self.rbuf.truncate(old_len);
                        break;
                    }
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {
                        self.rbuf.truncate(old_len);
                    }
                    Err(_) => {
                        self.rbuf.truncate(old_len);
                        return Sweep::close_now(true);
                    }
                }
            }
        }

        // ---- parse + execute phase --------------------------------------
        if !self.closing && (self.rpos < self.rbuf.len() || self.read_closed) {
            // Parse every complete frame first, claiming one admission slot
            // per request *up front*: a pipelined burst is admitted or shed
            // as the load it actually is, not serialized through one slot.
            let mut batch: Vec<(String, Option<AdmissionPermit>)> = Vec::new();
            let mut fatal: Option<Step> = None;
            loop {
                match self
                    .proto
                    .next_request(&self.rbuf, &mut self.rpos, self.read_closed)
                {
                    Step::Request(req) => {
                        let permit = admission.try_acquire();
                        if permit.is_none() {
                            engine.note_shed();
                        }
                        batch.push((req, permit));
                    }
                    Step::NeedMore => break,
                    step @ (Step::TooLong | Step::BadUtf8) => {
                        fatal = Some(step);
                        break;
                    }
                }
            }
            if !batch.is_empty() {
                progress = true;
            }
            for (req, permit) in batch {
                let resp = if permit.is_some() {
                    engine.execute(&req)
                } else {
                    "BUSY".to_string()
                };
                push_response(self.proto.mode(), &mut self.wbuf, &resp);
                drop(permit);
                if resp == "BYE" {
                    // Mirror the blocking server: nothing after QUIT is
                    // ever parsed or answered.
                    self.closing = true;
                    self.rpos = self.rbuf.len();
                    break;
                }
            }
            match fatal {
                Some(Step::TooLong) => {
                    progress = true;
                    let msg = match self.proto.mode() {
                        Mode::Binary => "ERR frame too long",
                        _ => "ERR line too long",
                    };
                    push_response(self.proto.mode(), &mut self.wbuf, msg);
                    self.closing = true;
                    self.read_closed = true;
                    self.rpos = self.rbuf.len();
                }
                Some(Step::BadUtf8) => {
                    // The blocking server's `lines()` erred out without a
                    // response; match it.
                    progress = true;
                    self.closing = true;
                    self.read_closed = true;
                    self.rpos = self.rbuf.len();
                }
                _ => {}
            }
            if self.read_closed && !self.closing {
                // EOF and everything parseable is answered (an incomplete
                // trailing frame can never complete): flush and finish.
                self.closing = true;
            }
            // Compact the consumed prefix so long-lived pipelined
            // connections don't accrete their whole history.
            if self.rpos == self.rbuf.len() {
                self.rbuf.clear();
                self.rpos = 0;
            } else if self.rpos > COMPACT_THRESHOLD {
                self.rbuf.drain(..self.rpos);
                self.rpos = 0;
            }
        }

        // ---- write phase ------------------------------------------------
        if self.pending_write() > 0 {
            loop {
                match self.stream.write(&self.wbuf[self.wpos..]) {
                    Ok(0) => return Sweep::close_now(progress),
                    Ok(n) => {
                        self.wpos += n;
                        progress = true;
                        if self.wpos == self.wbuf.len() {
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => return Sweep::close_now(progress),
                }
            }
            if self.wpos == self.wbuf.len() {
                self.wbuf.clear();
                self.wpos = 0;
            } else if self.wpos > COMPACT_THRESHOLD {
                self.wbuf.drain(..self.wpos);
                self.wpos = 0;
            }
        }

        if self.closing && self.pending_write() == 0 {
            return Sweep::close_now(progress);
        }

        if progress {
            self.last_active = now;
            self.idle_sweeps = 0;
        } else {
            self.idle_sweeps = self.idle_sweeps.saturating_add(1);
            if let Some(limit) = idle_timeout {
                if now.duration_since(self.last_active) >= limit {
                    return Sweep {
                        progress: false,
                        close: true,
                        idle_evicted: true,
                    };
                }
            }
        }
        Sweep {
            progress,
            close: false,
            idle_evicted: false,
        }
    }
}

/// Serve `engine` over TCP with the nonblocking front end until `shutdown`
/// flips true. Returns the bound address (port 0 supported). Threads are
/// detached, exactly like the blocking server: flip `shutdown` to stop.
pub fn serve_nonblocking<E: RequestHandler>(
    engine: Arc<E>,
    addr: &str,
    shutdown: Arc<AtomicBool>,
    opts: ServeOptions,
) -> Result<SocketAddr> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let shards = if opts.shards == 0 {
        default_service_shards()
    } else {
        opts.shards
    };
    let admission = AdmissionControl::new(opts.max_pending);
    let queues: Vec<BoundedQueue<TcpStream>> =
        (0..shards).map(|_| BoundedQueue::new(ACCEPT_QUEUE_CAP)).collect();
    for (i, queue) in queues.iter().enumerate() {
        let engine = Arc::clone(&engine);
        let queue = queue.clone();
        let admission = admission.clone();
        let shutdown = Arc::clone(&shutdown);
        let idle_timeout = opts.idle_timeout;
        std::thread::Builder::new()
            .name(format!("tor-shard-{i}"))
            .spawn(move || shard_loop(engine, queue, admission, shutdown, idle_timeout))
            .expect("spawn shard thread");
    }
    std::thread::Builder::new()
        .name("tor-acceptor".to_string())
        .spawn(move || acceptor_loop(listener, queues, engine, shutdown))
        .expect("spawn acceptor thread");
    Ok(local)
}

fn acceptor_loop<E: RequestHandler>(
    listener: TcpListener,
    queues: Vec<BoundedQueue<TcpStream>>,
    engine: Arc<E>,
    shutdown: Arc<AtomicBool>,
) {
    let mut next = 0usize;
    let mut backoff = IdleBackoff::new(50, 2000);
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                backoff.reset();
                // Counted on accept (as the blocking server did) so the
                // gauge never under-reports a connection awaiting its
                // shard; shards decrement on every close path.
                engine.conn_gauge().add(1);
                let mut stream = stream;
                'place: loop {
                    for k in 0..queues.len() {
                        let q = &queues[(next + k) % queues.len()];
                        match q.try_push(stream) {
                            Ok(()) => {
                                next = (next + k + 1) % queues.len();
                                break 'place;
                            }
                            Err(back) => stream = back,
                        }
                    }
                    // Every shard's handoff queue is full: wait for the
                    // loops to adopt their backlog rather than dropping
                    // the connection on the floor.
                    if shutdown.load(Ordering::Relaxed) {
                        engine.conn_gauge().sub(1);
                        break 'place;
                    }
                    std::thread::sleep(Duration::from_micros(500));
                }
            }
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => backoff.idle(),
            Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    for q in &queues {
        q.close();
    }
    // Orderly-stop drain: make the WAL durable whatever the fsync policy
    // and flush buffered telemetry, so flipping `shutdown` never drops
    // acknowledged mutations or emitted records.
    engine.shutdown_flush();
}

fn shard_loop<E: RequestHandler>(
    engine: Arc<E>,
    queue: BoundedQueue<TcpStream>,
    admission: AdmissionControl,
    shutdown: Arc<AtomicBool>,
    idle_timeout: Option<Duration>,
) {
    let mut conns: Slab<Conn> = Slab::new();
    let mut tokens: Vec<Token> = Vec::new();
    let mut backoff = IdleBackoff::new(50, 2000);
    let mut sweep_no: u64 = 0;
    while !shutdown.load(Ordering::Relaxed) {
        sweep_no = sweep_no.wrapping_add(1);
        let mut progress = false;
        // Adopt newly accepted connections.
        while let Some(stream) = queue.try_pop() {
            if stream.set_nonblocking(true).is_err() {
                engine.conn_gauge().sub(1);
                continue;
            }
            stream.set_nodelay(true).ok();
            conns.insert(Conn::new(stream));
            progress = true;
        }
        let now = Instant::now();
        conns.collect_tokens(&mut tokens);
        for &token in &tokens {
            let Some(conn) = conns.get_mut(token) else {
                continue;
            };
            // Cold-connection probe skipping: long-idle sockets are swept
            // only every COLD_PROBE_PERIOD-th pass (staggered by token) so
            // a mostly-idle 10k-connection herd doesn't cost 10k syscalls
            // per sweep. Connections with queued writes are never cold.
            let cold = conn.idle_sweeps >= COLD_AFTER_SWEEPS && conn.pending_write() == 0;
            if cold && (sweep_no.wrapping_add(token.0 as u64)) % COLD_PROBE_PERIOD != 0 {
                // Unprobed sweeps still advance the idle clock.
                conn.idle_sweeps = conn.idle_sweeps.saturating_add(1);
                if let Some(limit) = idle_timeout {
                    if now.duration_since(conn.last_active) >= limit {
                        conns.remove(token);
                        engine.conn_gauge().sub(1);
                        engine.note_idle_evicted();
                    }
                }
                continue;
            }
            let sweep = conn.service(&engine, &admission, now, idle_timeout);
            progress |= sweep.progress;
            if sweep.close {
                conns.remove(token);
                engine.conn_gauge().sub(1);
                if sweep.idle_evicted {
                    engine.note_idle_evicted();
                }
                progress = true;
            }
        }
        if progress {
            backoff.reset();
        } else {
            backoff.idle();
        }
    }
    // Shutdown: account for every connection this shard still owns, plus
    // any stranded in the handoff queue.
    for token in conns.tokens() {
        conns.remove(token);
        engine.conn_gauge().sub(1);
    }
    while queue.try_pop().is_some() {
        engine.conn_gauge().sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(state: &mut ProtoState, buf: &[u8], eof: bool) -> (Vec<String>, Option<Step>) {
        let mut pos = 0;
        let mut out = Vec::new();
        loop {
            match state.next_request(buf, &mut pos, eof) {
                Step::Request(r) => out.push(r),
                Step::NeedMore => return (out, None),
                terminal => return (out, Some(terminal)),
            }
        }
    }

    #[test]
    fn text_lines_parse_with_crlf_and_eof_tail() {
        let mut st = ProtoState::new();
        let (reqs, term) = feed(&mut st, b"STATS\r\nFIND a => b\nTAIL", true);
        assert_eq!(reqs, vec!["STATS", "FIND a => b", "TAIL"]);
        assert_eq!(term, None);
        assert_eq!(st.mode(), Mode::Text);
    }

    #[test]
    fn text_tail_without_eof_waits() {
        let mut st = ProtoState::new();
        let (reqs, term) = feed(&mut st, b"STATS\nPART", false);
        assert_eq!(reqs, vec!["STATS"]);
        assert_eq!(term, None);
    }

    #[test]
    fn binary_negotiation_and_frames() {
        let mut st = ProtoState::new();
        let mut buf = Vec::new();
        buf.extend_from_slice(BINARY_MAGIC);
        for payload in ["STATS", "QUIT"] {
            buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
            buf.extend_from_slice(payload.as_bytes());
        }
        let (reqs, term) = feed(&mut st, &buf, false);
        assert_eq!(reqs, vec!["STATS", "QUIT"]);
        assert_eq!(term, None);
        assert_eq!(st.mode(), Mode::Binary);
    }

    #[test]
    fn one_byte_fragments_reassemble_in_both_modes() {
        // Text, drip-fed a byte at a time into a growing buffer.
        let stream = b"RULES LIMIT 2\nSTATS\n";
        let mut st = ProtoState::new();
        let mut buf = Vec::new();
        let mut pos = 0;
        let mut got = Vec::new();
        for &b in stream {
            buf.push(b);
            loop {
                match st.next_request(&buf, &mut pos, false) {
                    Step::Request(r) => got.push(r),
                    Step::NeedMore => break,
                    other => panic!("unexpected {other:?}"),
                }
            }
        }
        assert_eq!(got, vec!["RULES LIMIT 2", "STATS"]);

        // Binary: the magic and the frame header may themselves fragment.
        let mut bin = Vec::new();
        bin.extend_from_slice(BINARY_MAGIC);
        bin.extend_from_slice(&5u32.to_be_bytes());
        bin.extend_from_slice(b"STATS");
        let mut st = ProtoState::new();
        let mut buf = Vec::new();
        let mut pos = 0;
        let mut got = Vec::new();
        for &b in &bin {
            buf.push(b);
            loop {
                match st.next_request(&buf, &mut pos, false) {
                    Step::Request(r) => got.push(r),
                    Step::NeedMore => break,
                    other => panic!("unexpected {other:?}"),
                }
            }
        }
        assert_eq!(got, vec!["STATS"]);
        assert_eq!(st.mode(), Mode::Binary);
    }

    #[test]
    fn short_first_line_negotiates_text() {
        // "A\n" is shorter than the magic but the newline proves text.
        let mut st = ProtoState::new();
        let (reqs, _) = feed(&mut st, b"A\n", false);
        assert_eq!(reqs, vec!["A"]);
        assert_eq!(st.mode(), Mode::Text);
        // A short EOF'd fragment likewise resolves to text.
        let mut st = ProtoState::new();
        let (reqs, _) = feed(&mut st, b"HI", true);
        assert_eq!(reqs, vec!["HI"]);
        // Three bytes of the magic alone: still undecidable.
        let mut st = ProtoState::new();
        let mut pos = 0;
        assert_eq!(st.next_request(b"RQL", &mut pos, false), Step::NeedMore);
        assert_eq!(st.mode(), Mode::Negotiating);
    }

    #[test]
    fn oversized_requests_are_rejected_in_both_modes() {
        let mut st = ProtoState::new();
        let long = vec![b'x'; MAX_REQUEST_BYTES + 1];
        let mut pos = 0;
        assert_eq!(st.next_request(&long, &mut pos, false), Step::TooLong);

        let mut st = ProtoState::new();
        let mut bin = Vec::new();
        bin.extend_from_slice(BINARY_MAGIC);
        bin.extend_from_slice(&((MAX_REQUEST_BYTES as u32) + 1).to_be_bytes());
        let mut pos = 0;
        assert_eq!(st.next_request(&bin, &mut pos, false), Step::TooLong);
        // But a maximal in-bounds line is fine.
        let mut st = ProtoState::new();
        let mut ok = vec![b'y'; MAX_REQUEST_BYTES];
        ok.push(b'\n');
        let mut pos = 0;
        match st.next_request(&ok, &mut pos, false) {
            Step::Request(r) => assert_eq!(r.len(), MAX_REQUEST_BYTES),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn oversized_line_rejected_even_with_newline_buffered() {
        // Fragmentation must not change the verdict: a line one byte past
        // the cap is TooLong even when its terminating newline arrived in
        // the same read (the blocking server's capped read never sees the
        // newline at all, so both servers must reject).
        let mut st = ProtoState::new();
        let mut buf = vec![b'x'; MAX_REQUEST_BYTES + 1];
        buf.push(b'\n');
        buf.extend_from_slice(b"STATS\n");
        let mut pos = 0;
        assert_eq!(st.next_request(&buf, &mut pos, false), Step::TooLong);
        assert_eq!(pos, 0, "TooLong must not consume");
    }

    #[test]
    fn invalid_utf8_is_fatal() {
        let mut st = ProtoState::new();
        let (reqs, term) = feed(&mut st, b"STATS\n\xff\xfe\n", false);
        assert_eq!(reqs, vec!["STATS"]);
        assert_eq!(term, Some(Step::BadUtf8));
    }

    #[test]
    fn push_response_frames_per_mode() {
        let mut wbuf = Vec::new();
        push_response(Mode::Text, &mut wbuf, "OK");
        assert_eq!(wbuf, b"OK\n");
        let mut wbuf = Vec::new();
        push_response(Mode::Binary, &mut wbuf, "OK");
        assert_eq!(&wbuf[..4], &2u32.to_be_bytes());
        assert_eq!(&wbuf[4..], b"OK");
    }
}
