//! Minimal, dependency-free event-registry plumbing for the nonblocking
//! service front end (`coordinator/frontend.rs`).
//!
//! The vendor set has no `libc`, so there is no `poll(2)`/`epoll(7)` to
//! park on. Instead the front end runs *readiness-by-attempt*: every socket
//! is `set_nonblocking(true)` and a sweep simply attempts the I/O it is
//! interested in — a `WouldBlock` return **is** the "not ready" signal.
//! What this module provides is the mio-shaped bookkeeping around that
//! idea:
//!
//! * [`Token`] / [`Slab`] — a stable-index connection registry (mio's
//!   `Token` + slab idiom) with O(1) insert/remove and free-slot reuse, so
//!   connection identity survives neighbours closing.
//! * [`Interest`] — the READ/WRITE readiness set a connection currently
//!   wants, used to skip attempts that cannot progress (e.g. no read probe
//!   while the write buffer is over its high-water mark).
//! * [`IdleBackoff`] — exponential sleep escalation (50 µs → 2 ms) for
//!   sweeps that made no progress, bounding idle CPU without adding more
//!   than ~2 ms of latency to a cold wakeup.

use std::time::Duration;

/// Stable identifier of a registered connection (an index into a [`Slab`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Token(pub usize);

/// Readiness interest set: which I/O directions a connection wants probed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest(u8);

impl Interest {
    pub const NONE: Interest = Interest(0);
    pub const READ: Interest = Interest(1);
    pub const WRITE: Interest = Interest(2);

    pub fn with(self, other: Interest) -> Interest {
        Interest(self.0 | other.0)
    }

    pub fn readable(self) -> bool {
        self.0 & Interest::READ.0 != 0
    }

    pub fn writable(self) -> bool {
        self.0 & Interest::WRITE.0 != 0
    }

    pub fn is_none(self) -> bool {
        self.0 == 0
    }
}

/// Vec-backed slab with a free list: insert returns a [`Token`] that stays
/// valid (and is never reassigned to another live entry) until `remove`.
#[derive(Debug)]
pub struct Slab<T> {
    slots: Vec<Option<T>>,
    free: Vec<usize>,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab {
            slots: Vec::new(),
            free: Vec::new(),
            len: 0,
        }
    }

    pub fn insert(&mut self, value: T) -> Token {
        self.len += 1;
        match self.free.pop() {
            Some(i) => {
                debug_assert!(self.slots[i].is_none());
                self.slots[i] = Some(value);
                Token(i)
            }
            None => {
                self.slots.push(Some(value));
                Token(self.slots.len() - 1)
            }
        }
    }

    pub fn remove(&mut self, token: Token) -> Option<T> {
        let slot = self.slots.get_mut(token.0)?;
        let value = slot.take()?;
        self.free.push(token.0);
        self.len -= 1;
        Some(value)
    }

    pub fn get_mut(&mut self, token: Token) -> Option<&mut T> {
        self.slots.get_mut(token.0)?.as_mut()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Iterate occupied slots in token order.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = (Token, &mut T)> {
        self.slots
            .iter_mut()
            .enumerate()
            .filter_map(|(i, slot)| slot.as_mut().map(|v| (Token(i), v)))
    }

    /// Tokens of occupied slots, collected (for remove-while-iterating).
    pub fn tokens(&self) -> Vec<Token> {
        let mut out = Vec::new();
        self.collect_tokens(&mut out);
        out
    }

    /// Like [`Slab::tokens`], reusing the caller's buffer so a hot sweep
    /// loop does not allocate per iteration.
    pub fn collect_tokens(&self, out: &mut Vec<Token>) {
        out.clear();
        out.extend(
            self.slots
                .iter()
                .enumerate()
                .filter_map(|(i, slot)| slot.as_ref().map(|_| Token(i))),
        );
    }
}

/// Exponential idle backoff for readiness-by-attempt sweeps.
#[derive(Debug)]
pub struct IdleBackoff {
    current_us: u64,
    min_us: u64,
    max_us: u64,
}

impl IdleBackoff {
    pub fn new(min_us: u64, max_us: u64) -> Self {
        assert!(min_us > 0 && min_us <= max_us);
        IdleBackoff {
            current_us: min_us,
            min_us,
            max_us,
        }
    }

    /// A sweep made progress: next idle sleep restarts at the minimum.
    pub fn reset(&mut self) {
        self.current_us = self.min_us;
    }

    /// A sweep made no progress: sleep, then double toward the cap.
    pub fn idle(&mut self) {
        std::thread::sleep(Duration::from_micros(self.current_us));
        self.current_us = (self.current_us * 2).min(self.max_us);
    }

    /// Current sleep length (exposed for tests; no side effects).
    pub fn current(&self) -> Duration {
        Duration::from_micros(self.current_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interest_bits_compose() {
        let both = Interest::READ.with(Interest::WRITE);
        assert!(both.readable() && both.writable());
        assert!(Interest::READ.readable() && !Interest::READ.writable());
        assert!(!Interest::WRITE.readable() && Interest::WRITE.writable());
        assert!(Interest::NONE.is_none());
        assert!(!both.is_none());
    }

    #[test]
    fn slab_reuses_freed_slots_and_keeps_neighbours() {
        let mut slab = Slab::new();
        let a = slab.insert("a");
        let b = slab.insert("b");
        let c = slab.insert("c");
        assert_eq!(slab.len(), 3);
        assert_eq!(slab.remove(b), Some("b"));
        assert_eq!(slab.len(), 2);
        assert_eq!(slab.remove(b), None, "double remove is None");
        assert_eq!(slab.get_mut(a), Some(&mut "a"));
        assert_eq!(slab.get_mut(c), Some(&mut "c"));
        let d = slab.insert("d");
        assert_eq!(d, b, "freed slot is reused");
        let tokens = slab.tokens();
        assert_eq!(tokens, vec![a, d, c]);
        let seen: Vec<_> = slab.iter_mut().map(|(t, v)| (t, *v)).collect();
        assert_eq!(seen, vec![(a, "a"), (d, "d"), (c, "c")]);
    }

    #[test]
    fn slab_grows_past_initial_allocations() {
        let mut slab = Slab::new();
        let tokens: Vec<Token> = (0..100).map(|i| slab.insert(i)).collect();
        for (i, t) in tokens.iter().enumerate() {
            assert_eq!(slab.get_mut(*t), Some(&mut (i as i32)));
        }
        for t in tokens.iter().step_by(2) {
            slab.remove(*t);
        }
        assert_eq!(slab.len(), 50);
        for _ in 0..50 {
            slab.insert(-1);
        }
        assert_eq!(slab.len(), 100);
        assert_eq!(
            slab.tokens().len(),
            100,
            "free-list reuse must not clobber live slots"
        );
    }

    #[test]
    fn backoff_escalates_and_resets() {
        let mut b = IdleBackoff::new(50, 2000);
        assert_eq!(b.current(), Duration::from_micros(50));
        b.idle();
        assert_eq!(b.current(), Duration::from_micros(100));
        for _ in 0..10 {
            b.idle();
        }
        assert_eq!(b.current(), Duration::from_micros(2000), "capped");
        b.reset();
        assert_eq!(b.current(), Duration::from_micros(50));
    }
}
