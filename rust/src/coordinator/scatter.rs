//! Scatter-gather coordinator for sharded serving (DESIGN.md §18).
//!
//! Deployment shape: N `tor serve --shard-of k/N` shard processes, each a
//! full replica of the store (typically a v4 mmap snapshot plus its own
//! WAL), fronted by one coordinator (`tor serve --shards a:p,b:q,...`)
//! built around [`ScatterEngine`]. The *data* is replicated; the *work*
//! is sharded: a `RULES` query is scattered as `SCATTER k/N <line>` so
//! shard `k` executes only partition `k` of the subtree-aligned partition
//! map ([`crate::query::parallel::ParallelExecutor::execute_view_partition`])
//! and answers a machine-mergeable `PARTIAL` frame. The coordinator merges
//! the partials under the engine's total output order — `(sort key under
//! `f64::total_cmp`, then rule)` — which is insertion-order independent,
//! so the merged `RULES` response is **byte-identical** to a single-node
//! engine's at any shard count.
//!
//! Everything that is not a scatterable row query takes one of two other
//! routes:
//!
//! * **Forward** (`EXPLAIN`/`FIND`/`TOP`/`CONSEQ`/`SUPPORT`, plus
//!   `SNAPSHOT`): every shard holds the whole store, so one shard answers
//!   the whole request. The target is picked by hashing the request line
//!   through a [`ShardRouter`] over the live shards, so point lookups
//!   spread across the fleet and a shard death just rebalances the slot
//!   map (the exact two-pass rebalance `sharding.rs` now implements).
//! * **Broadcast** (`INGEST`/`COMPACT`): applied to every shard under a
//!   write gate that excludes in-flight scatters, so replicas move in
//!   lock-step and every scatter observes one consistent generation.
//!   Mutations are *refused* while any shard is down — a down shard can
//!   never silently diverge from the fleet.
//!
//! **Degradation.** A shard that fails a request (after one reconnect
//! attempt) is marked down — sticky, like a single-node engine's degraded
//! durability mode — the router rebalances onto the survivors, and the
//! `tor_shard_down` gauge rises. Scatters keep answering from the live
//! partitions with an explicit partial-result flag in the header
//! (`RULES <n> partial shards_down=<d>`); partial responses are never
//! cached.
//!
//! The coordinator keeps its own generation counter (bumped per
//! successful broadcast mutation) keying an optional [`ResultCache`], and
//! implements [`RequestHandler`], so the nonblocking front end
//! ([`super::frontend`]) serves it over the same two wire framings as a
//! single shard.

use std::collections::BTreeMap;
use std::io::{self, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{ensure, Context, Result};

use super::frontend::{RequestHandler, BINARY_MAGIC};
use super::sharding::ShardRouter;
use crate::data::vocab::ItemId;
use crate::obs::registry::{Counter, Gauge, MetricsRegistry};
use crate::query::ast::SortSpec;
use crate::query::cache::ResultCache;
use crate::query::exec::{Accumulator, ExecStats, Row};
use crate::rules::metrics::RuleMetrics;
use crate::rules::rule::Rule;

/// Sanity cap on one shard response frame (a full-ruleset partial on a
/// large build is megabytes; corrupt length prefixes are gigabytes).
const MAX_RESPONSE_BYTES: usize = 256 * 1024 * 1024;

/// Router slot count: comfortably more slots than any realistic shard
/// fleet, so the ±1-slot rebalance bound stays fine-grained.
const ROUTER_SLOTS: usize = 64;

// ---------------------------------------------------------------------
// PARTIAL row codec
// ---------------------------------------------------------------------

/// Encode one result row for a `PARTIAL` frame (no trailing newline):
///
/// ```text
/// R <ant ids csv>|<con ids csv> <10 metric f64s as 016x bit patterns csv>\t<rendered>
/// ```
///
/// Item ids and raw `f64::to_bits` patterns make the decode lossless (the
/// coordinator re-sorts under `f64::total_cmp`, so NaN/∞ metric values
/// must survive the wire exactly); the pre-rendered display text rides
/// along after the tab so the coordinator can emit byte-identical `RULES`
/// lines without holding the vocab.
pub(crate) fn encode_partial_row(row: &Row, rendered: &str) -> String {
    let side = |items: &[ItemId]| {
        items
            .iter()
            .map(|i| i.to_string())
            .collect::<Vec<_>>()
            .join(",")
    };
    let m = &row.metrics;
    let bits = [
        m.support,
        m.confidence,
        m.lift,
        m.leverage,
        m.conviction,
        m.zhang,
        m.jaccard,
        m.cosine,
        m.kulczynski,
        m.yule_q,
    ]
    .iter()
    .map(|v| format!("{:016x}", v.to_bits()))
    .collect::<Vec<_>>()
    .join(",");
    format!(
        "R {}|{} {}\t{}",
        side(row.rule.antecedent.items()),
        side(row.rule.consequent.items()),
        bits,
        rendered
    )
}

/// Decode one [`encode_partial_row`] line back into the row and its
/// pre-rendered display text.
pub(crate) fn decode_partial_row(line: &str) -> Result<(Row, String)> {
    let (head, rendered) = line
        .split_once('\t')
        .context("partial row: missing rendered text")?;
    let head = head
        .strip_prefix("R ")
        .context("partial row: missing `R ` tag")?;
    let (rule, bits) = head
        .split_once(' ')
        .context("partial row: missing metric vector")?;
    let (ant, con) = rule
        .split_once('|')
        .context("partial row: missing `|` side separator")?;
    let parse_side = |s: &str| -> Result<Vec<ItemId>> {
        s.split(',')
            .map(|t| t.parse::<ItemId>().with_context(|| format!("bad item id `{t}`")))
            .collect()
    };
    let mut vals = [0f64; 10];
    let mut toks = bits.split(',');
    for slot in &mut vals {
        let t = toks.next().context("partial row: short metric vector")?;
        *slot = f64::from_bits(
            u64::from_str_radix(t, 16).with_context(|| format!("bad metric bits `{t}`"))?,
        );
    }
    ensure!(toks.next().is_none(), "partial row: oversized metric vector");
    let metrics = RuleMetrics {
        support: vals[0],
        confidence: vals[1],
        lift: vals[2],
        leverage: vals[3],
        conviction: vals[4],
        zhang: vals[5],
        jaccard: vals[6],
        cosine: vals[7],
        kulczynski: vals[8],
        yule_q: vals[9],
    };
    let row = Row {
        rule: Rule::from_ids(parse_side(ant)?, parse_side(con)?),
        metrics,
    };
    Ok((row, rendered.to_string()))
}

/// One shard's decoded `PARTIAL` response.
pub(crate) struct PartialFrame {
    /// The shard's serving generation when it executed its partition. The
    /// coordinator's write gate keeps broadcast mutations out of in-flight
    /// scatters, so every frame of one scatter must agree.
    pub generation: u64,
    /// This partition's exact work counters; summing over a covering set
    /// of frames reproduces the single-node `ExecStats`.
    pub stats: ExecStats,
    pub rows: Vec<(Row, String)>,
}

/// Parse one shard's `PARTIAL <n> gen=<g> scanned=<s> candidates=<c>
/// matched=<m>` response (header plus row lines).
pub(crate) fn parse_partial(resp: &str) -> Result<PartialFrame> {
    let mut lines = resp.lines();
    let header = lines.next().context("empty shard response")?;
    let rest = header
        .strip_prefix("PARTIAL ")
        .with_context(|| format!("not a PARTIAL response: `{header}`"))?;
    let mut toks = rest.split(' ');
    let count: usize = toks
        .next()
        .context("partial header: missing row count")?
        .parse()
        .context("partial header: bad row count")?;
    let mut generation = None;
    let mut stats = ExecStats::default();
    for t in toks {
        let (k, v) = t
            .split_once('=')
            .with_context(|| format!("partial header: bad field `{t}`"))?;
        let v: u64 = v
            .parse()
            .with_context(|| format!("partial header: bad value `{t}`"))?;
        match k {
            "gen" => generation = Some(v),
            "scanned" => stats.scanned = v as usize,
            "candidates" => stats.candidates = v as usize,
            "matched" => stats.matched = v as usize,
            other => anyhow::bail!("partial header: unknown field `{other}`"),
        }
    }
    let rows: Vec<(Row, String)> = lines.map(decode_partial_row).collect::<Result<_>>()?;
    ensure!(
        rows.len() == count,
        "partial header claims {count} rows, got {}",
        rows.len()
    );
    Ok(PartialFrame {
        generation: generation.context("partial header: missing gen=")?,
        stats,
        rows,
    })
}

/// Merge partial frames into the final `RULES` response. The accumulator
/// re-imposes the engine's total output order, so the result is
/// independent of frame order and of how rows were split across shards;
/// with every partition present the bytes equal a single-node response.
/// `shards_down > 0` flags the response as partial in the header (those
/// partitions' rows are simply absent).
pub(crate) fn merge_rules_response(
    sort: Option<SortSpec>,
    limit: Option<usize>,
    frames: Vec<PartialFrame>,
    shards_down: usize,
) -> Result<String> {
    if let Some(first) = frames.first() {
        ensure!(
            frames.iter().all(|f| f.generation == first.generation),
            "inconsistent shard generations (out-of-band mutation?)"
        );
    }
    let mut acc = Accumulator::new(sort, limit);
    let mut rendered: BTreeMap<Rule, String> = BTreeMap::new();
    for frame in frames {
        for (row, text) in frame.rows {
            rendered.insert(row.rule.clone(), text);
            acc.push(row);
        }
    }
    let rows = acc.finish();
    let mut out = if shards_down == 0 {
        format!("RULES {}\n", rows.len())
    } else {
        format!("RULES {} partial shards_down={shards_down}\n", rows.len())
    };
    for row in &rows {
        out.push_str(
            rendered
                .get(&row.rule)
                .context("merged row lost its rendering")?,
        );
        out.push('\n');
    }
    out.pop();
    Ok(out)
}

// ---------------------------------------------------------------------
// shard connections
// ---------------------------------------------------------------------

/// One shard's client half of the `RQL2` binary framing: lazily connected,
/// length-prefixed frames, strictly request→response (the coordinator
/// never pipelines on a shard connection, so a frame read is always the
/// answer to the frame just written).
struct ShardConn {
    addr: String,
    stream: Option<TcpStream>,
    /// Sticky failure flag: set after a request fails post-reconnect;
    /// a down shard is never retried (replica divergence would be
    /// undetectable after missed mutations).
    down: bool,
}

impl ShardConn {
    fn new(addr: String) -> Self {
        ShardConn {
            addr,
            stream: None,
            down: false,
        }
    }

    fn ensure_connected(&mut self) -> io::Result<&mut TcpStream> {
        if self.stream.is_none() {
            let mut s = TcpStream::connect(&self.addr)?;
            s.set_nodelay(true).ok();
            s.write_all(BINARY_MAGIC)?;
            self.stream = Some(s);
        }
        Ok(self.stream.as_mut().expect("just connected"))
    }

    fn try_send(&mut self, payload: &str) -> io::Result<()> {
        let s = self.ensure_connected()?;
        s.write_all(&(payload.len() as u32).to_be_bytes())?;
        s.write_all(payload.as_bytes())
    }

    /// Write one request frame, reconnecting once on failure (a dead
    /// cached connection from an earlier idle eviction looks like a write
    /// error; the reconnect discards the half-sent frame, so nothing can
    /// be applied twice).
    fn send(&mut self, payload: &str) -> io::Result<()> {
        match self.try_send(payload) {
            Ok(()) => Ok(()),
            Err(_) => {
                self.stream = None;
                self.try_send(payload)
            }
        }
    }

    /// Read one response frame. No retry: the request may already be
    /// executing on the shard, and replaying a mutation would double-apply.
    fn recv(&mut self) -> io::Result<String> {
        let s = self
            .stream
            .as_mut()
            .ok_or_else(|| io::Error::new(ErrorKind::NotConnected, "no shard connection"))?;
        let mut len = [0u8; 4];
        s.read_exact(&mut len)?;
        let len = u32::from_be_bytes(len) as usize;
        if len > MAX_RESPONSE_BYTES {
            self.stream = None;
            return Err(io::Error::new(
                ErrorKind::InvalidData,
                format!("shard response frame of {len} bytes"),
            ));
        }
        let mut buf = vec![0u8; len];
        s.read_exact(&mut buf)?;
        String::from_utf8(buf).map_err(|e| io::Error::new(ErrorKind::InvalidData, e))
    }

    fn request(&mut self, payload: &str) -> io::Result<String> {
        self.send(payload)?;
        match self.recv() {
            Ok(resp) => Ok(resp),
            Err(e) => {
                self.stream = None;
                Err(e)
            }
        }
    }
}

/// Slot map over the *live* shards: `router` routes a request hash to a
/// worker index, `live[worker]` names the shard. Kept consistent by
/// [`ScatterEngine::refresh_router`]: a shard death shrinks the worker
/// count through [`ShardRouter::rebalance`] (minimal movement, ±1 slot
/// uniform), so surviving shards keep most of their slots.
struct RouterState {
    router: ShardRouter,
    live: Vec<usize>,
}

/// FNV-1a, the line hash that spreads forwarded point lookups.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Whether a request line may be answered from the coordinator cache —
/// the same rule the single-node engine applies: pure query verbs only,
/// never ANALYZE runs.
fn cacheable_line(line: &str) -> bool {
    let cmd = line.split_whitespace().next().unwrap_or("");
    matches!(
        cmd.to_ascii_uppercase().as_str(),
        "RULES" | "EXPLAIN" | "FIND" | "TOP" | "CONSEQ" | "SUPPORT"
    ) && !line
        .split_whitespace()
        .any(|t| t.eq_ignore_ascii_case("ANALYZE"))
}

/// Whether a rendered response carries the degraded partial-result flag
/// (such responses are never cached: a later identical query should see
/// the current fleet, not a snapshot of an earlier outage).
fn response_is_partial(resp: &str) -> bool {
    resp.lines()
        .next()
        .is_some_and(|h| h.contains(" partial shards_down="))
}

// ---------------------------------------------------------------------
// the coordinator engine
// ---------------------------------------------------------------------

/// Scatter-gather coordinator over a fleet of shard engines (module docs
/// above). Construct with [`ScatterEngine::new`], serve through
/// [`super::frontend::serve_nonblocking`].
pub struct ScatterEngine {
    shards: Vec<Mutex<ShardConn>>,
    /// Readers = scatters/forwards, writer = broadcast mutations: every
    /// scatter observes one generation across all shards.
    gate: RwLock<()>,
    router: Mutex<RouterState>,
    /// Coordinator generation: bumped per successful broadcast mutation;
    /// keys the result cache.
    generation: AtomicU64,
    cache: Option<ResultCache>,
    registry: Arc<MetricsRegistry>,
    active_conns: Gauge,
    shed_requests: Counter,
    idle_evicted_conns: Counter,
    /// `tor_shard_down`: how many shards are currently marked down.
    shard_down: Gauge,
    scatters: Counter,
    forwards: Counter,
    broadcasts: Counter,
    cache_hits: Counter,
    cache_misses: Counter,
}

impl ScatterEngine {
    /// Coordinator over shard addresses (`host:port`, one per shard, in
    /// partition order: `addrs[k]` must be the `--shard-of k/N` process).
    pub fn new(addrs: Vec<String>) -> Self {
        assert!(!addrs.is_empty(), "scatter coordinator needs ≥1 shard");
        let n = addrs.len();
        let registry = Arc::new(MetricsRegistry::new());
        ScatterEngine {
            shards: addrs.into_iter().map(|a| Mutex::new(ShardConn::new(a))).collect(),
            gate: RwLock::new(()),
            router: Mutex::new(RouterState {
                router: ShardRouter::new(n, ROUTER_SLOTS.max(n)),
                live: (0..n).collect(),
            }),
            generation: AtomicU64::new(0),
            cache: None,
            active_conns: registry.gauge("tor_active_connections"),
            shed_requests: registry.counter("tor_shed_requests_total"),
            idle_evicted_conns: registry.counter("tor_idle_evicted_conns_total"),
            shard_down: registry.gauge("tor_shard_down"),
            scatters: registry.counter("tor_scatter_requests_total"),
            forwards: registry.counter("tor_forwarded_requests_total"),
            broadcasts: registry.counter("tor_broadcast_requests_total"),
            cache_hits: registry.counter("tor_result_cache_hits_total"),
            cache_misses: registry.counter("tor_result_cache_misses_total"),
            registry,
        }
    }

    /// Attach a generation-keyed result cache of `mb` MiB (0 = none), the
    /// coordinator analogue of `QueryEngine::with_result_cache`.
    pub fn with_result_cache(mut self, mb: usize) -> Self {
        if mb > 0 {
            self.cache = Some(ResultCache::with_capacity_mb(mb));
        }
        self
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shards currently marked down (sticky).
    pub fn shards_down(&self) -> usize {
        self.shards.iter().filter(|s| s.lock().unwrap().down).count()
    }

    pub fn registry(&self) -> Arc<MetricsRegistry> {
        Arc::clone(&self.registry)
    }

    /// Execute one request line — the coordinator's whole protocol
    /// surface. Routing per verb is described in the module docs.
    pub fn execute(&self, line: &str) -> String {
        let line = line.trim();
        let (cmd, rest) = line.split_once(' ').unwrap_or((line, ""));
        let cmd = cmd.to_ascii_uppercase();
        match cmd.as_str() {
            "RULES" => self.execute_read(line, |engine| engine.scatter_rules(line)),
            "EXPLAIN" | "FIND" | "TOP" | "CONSEQ" | "SUPPORT" => {
                self.execute_read(line, |engine| engine.forward(line))
            }
            "INGEST" | "COMPACT" => self.broadcast_mutation(line),
            "SNAPSHOT" => {
                let _gate = self.gate.read().unwrap();
                self.forward_first(line)
            }
            "STATS" => self.cmd_stats(),
            "METRICS" => self.cmd_metrics(rest),
            "SCATTER" => "ERR SCATTER is shard-internal; send RULES to the coordinator".to_string(),
            "QUIT" => "BYE".to_string(),
            other => format!("ERR unknown command `{other}`"),
        }
    }

    /// Read-path wrapper: pin the read gate (excluding broadcast
    /// mutations for the whole request, so the generation loaded here is
    /// the one every shard answers under), then serve cache-aware.
    fn execute_read(&self, line: &str, run: impl FnOnce(&Self) -> String) -> String {
        let _gate = self.gate.read().unwrap();
        let generation = self.generation.load(Ordering::Acquire);
        let use_cache = self.cache.is_some() && cacheable_line(line);
        if use_cache {
            let cache = self.cache.as_ref().expect("checked above");
            if let Some(hit) = cache.get(generation, line) {
                self.cache_hits.inc();
                return hit.to_string();
            }
            self.cache_misses.inc();
        }
        let resp = run(self);
        if use_cache && !resp.starts_with("ERR") && !response_is_partial(&resp) {
            self.cache
                .as_ref()
                .expect("checked above")
                .insert(generation, line, &resp);
        }
        resp
    }

    /// Recompute the live-shard set from the sticky down flags, shrink the
    /// router onto the survivors, refresh `tor_shard_down`. Callers must
    /// not hold any shard-connection lock (lock order: conns → router).
    fn refresh_router(&self) {
        let live: Vec<usize> = (0..self.shards.len())
            .filter(|&k| !self.shards[k].lock().unwrap().down)
            .collect();
        self.shard_down.set((self.shards.len() - live.len()) as i64);
        let mut rs = self.router.lock().unwrap();
        if rs.live != live {
            if !live.is_empty() {
                rs.router.rebalance(live.len());
            }
            rs.live = live;
        }
    }

    /// Scatter `SCATTER k/n <line>` to every live shard, gather the
    /// `PARTIAL` frames, merge. Sends fan out before the first read, so
    /// the shards' partition executions overlap in wall time.
    fn scatter_rules(&self, line: &str) -> String {
        self.scatters.inc();
        // Parse locally: an unparseable query costs no fan-out, and the
        // merge needs the query's sort/limit (which bind pass-through
        // leaves exactly as written — no vocab required).
        let query = match crate::query::parser::parse(line) {
            Ok(q) => q,
            Err(e) => return format!("ERR {e:#}"),
        };
        let n = self.shards.len();
        let mut guards: Vec<_> = self.shards.iter().map(|s| s.lock().unwrap()).collect();
        // Scatter pass: one request frame per live shard.
        let mut sent = vec![false; n];
        for (k, conn) in guards.iter_mut().enumerate() {
            if conn.down {
                continue;
            }
            let req = format!("SCATTER {k}/{n} {line}");
            match conn.send(&req) {
                Ok(()) => sent[k] = true,
                Err(_) => conn.down = true,
            }
        }
        // Gather pass, in shard order. Every in-flight response is
        // drained even when an earlier one already decided the outcome —
        // an unread frame would desynchronize that connection's strict
        // request→response pairing for the *next* query.
        let mut responses: Vec<Option<String>> = vec![None; n];
        for (k, conn) in guards.iter_mut().enumerate() {
            if !sent[k] {
                continue;
            }
            match conn.recv() {
                Ok(resp) => responses[k] = Some(resp),
                Err(_) => {
                    conn.stream = None;
                    conn.down = true;
                }
            }
        }
        let down = guards.iter().filter(|c| c.down).count();
        drop(guards);
        self.refresh_router();
        let mut frames = Vec::new();
        for (k, resp) in responses.into_iter().enumerate() {
            let Some(resp) = resp else { continue };
            if resp.starts_with("ERR") {
                // Parse/plan errors are deterministic across replicas;
                // the first shard's wording is every shard's wording.
                return resp;
            }
            match parse_partial(&resp) {
                Ok(frame) => frames.push(frame),
                Err(e) => return format!("ERR shard {k} sent an unmergeable partial: {e:#}"),
            }
        }
        if frames.is_empty() {
            return "ERR no shards available".to_string();
        }
        match merge_rules_response(query.sort, query.limit, frames, down) {
            Ok(resp) => resp,
            Err(e) => format!("ERR {e:#}"),
        }
    }

    /// Forward a whole request to one live shard picked by line hash;
    /// on transport failure mark the shard down, rebalance, and retry on
    /// a survivor (the response is whole either way — every shard is a
    /// full replica).
    fn forward(&self, line: &str) -> String {
        self.forwards.inc();
        loop {
            let target = {
                let rs = self.router.lock().unwrap();
                if rs.live.is_empty() {
                    return "ERR no shards available".to_string();
                }
                rs.live[rs.router.route(fnv1a(line))]
            };
            let mut conn = self.shards[target].lock().unwrap();
            if conn.down {
                // Raced a concurrent mark-down; rebalance and re-route.
                drop(conn);
                self.refresh_router();
                continue;
            }
            match conn.request(line) {
                Ok(resp) => return resp,
                Err(_) => {
                    conn.down = true;
                    drop(conn);
                    self.refresh_router();
                }
            }
        }
    }

    /// Forward to the lowest-numbered live shard (SNAPSHOT: one artifact,
    /// deterministic author).
    fn forward_first(&self, line: &str) -> String {
        for k in 0..self.shards.len() {
            let mut conn = self.shards[k].lock().unwrap();
            if conn.down {
                continue;
            }
            match conn.request(line) {
                Ok(resp) => return resp,
                Err(_) => {
                    conn.down = true;
                    drop(conn);
                    self.refresh_router();
                }
            }
        }
        "ERR no shards available".to_string()
    }

    /// Apply a mutation to every shard under the write gate. Refused
    /// while any shard is down (a shard that misses a mutation could
    /// never rejoin coherently); a transport failure mid-broadcast marks
    /// that shard down — it is out of the fleet, the survivors stay in
    /// lock-step. All replicas compute the same response; any divergence
    /// is surfaced, not hidden.
    fn broadcast_mutation(&self, line: &str) -> String {
        self.broadcasts.inc();
        let _gate = self.gate.write().unwrap();
        let mut guards: Vec<_> = self.shards.iter().map(|s| s.lock().unwrap()).collect();
        if let Some(k) = guards.iter().position(|c| c.down) {
            return format!("ERR shard {k} is down; mutation refused to prevent replica divergence");
        }
        let n = guards.len();
        let mut sent = vec![false; n];
        for (k, conn) in guards.iter_mut().enumerate() {
            if conn.send(line).is_ok() {
                sent[k] = true;
            } else {
                conn.down = true;
            }
        }
        let mut responses: Vec<Option<String>> = vec![None; n];
        for (k, conn) in guards.iter_mut().enumerate() {
            if !sent[k] {
                continue;
            }
            match conn.recv() {
                Ok(resp) => responses[k] = Some(resp),
                Err(_) => {
                    conn.stream = None;
                    conn.down = true;
                }
            }
        }
        drop(guards);
        self.refresh_router();
        let mut answered = responses.iter().flatten();
        let Some(first) = answered.next().cloned() else {
            return "ERR no shards available".to_string();
        };
        if let Some(other) = answered.find(|r| **r != first) {
            return format!("ERR shard responses diverged: `{first}` vs `{other}`");
        }
        if !first.starts_with("ERR") {
            self.generation.fetch_add(1, Ordering::Release);
            if let Some(cache) = &self.cache {
                cache.clear();
            }
        }
        first
    }

    /// `STATS`: a live shard's full STATS line plus an append-only
    /// coordinator tail (fleet size, liveness, scatter count) — same
    /// append-only discipline as the shard-side tails.
    fn cmd_stats(&self) -> String {
        let _gate = self.gate.read().unwrap();
        let resp = self.forward_first("STATS");
        if resp.starts_with("ERR") {
            return resp;
        }
        let down = self.shards_down();
        format!(
            "{resp} shards={} shards_up={} shards_down={} scatters={}",
            self.shards.len(),
            self.shards.len() - down,
            down,
            self.scatters.get()
        )
    }

    /// `METRICS [JSON]` over the coordinator's own registry, in the exact
    /// rendering the shard engine uses.
    fn cmd_metrics(&self, rest: &str) -> String {
        match rest.trim().to_ascii_uppercase().as_str() {
            "" => {
                let body = self.registry.render_prometheus();
                let body = body.trim_end();
                format!("METRICS {}\n{body}", body.lines().count())
            }
            "JSON" => format!("METRICS JSON {}", self.registry.to_json().to_string_compact()),
            _ => "ERR usage: METRICS [JSON]".to_string(),
        }
    }
}

impl RequestHandler for ScatterEngine {
    fn execute(&self, line: &str) -> String {
        ScatterEngine::execute(self, line)
    }
    fn conn_gauge(&self) -> Gauge {
        self.active_conns.clone()
    }
    fn note_shed(&self) {
        self.shed_requests.inc();
    }
    fn note_idle_evicted(&self) {
        self.idle_evicted_conns.inc();
    }
    fn shutdown_flush(&self) {
        // Nothing to flush: all durable state lives on the shards, and
        // their own serve loops flush on shutdown.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(seed: f64) -> RuleMetrics {
        RuleMetrics {
            support: seed,
            confidence: seed / 2.0,
            lift: seed * 3.0,
            leverage: -seed,
            conviction: f64::INFINITY,
            zhang: 0.0,
            jaccard: seed / 7.0,
            cosine: seed.sqrt(),
            kulczynski: 1.0 - seed,
            yule_q: f64::from_bits(0x7ff8_0000_0000_0001), // a specific NaN payload
        }
    }

    fn row(ant: Vec<u32>, con: Vec<u32>, seed: f64) -> Row {
        Row {
            rule: Rule::from_ids(ant, con),
            metrics: metrics(seed),
        }
    }

    fn bits_of(m: &RuleMetrics) -> [u64; 10] {
        [
            m.support.to_bits(),
            m.confidence.to_bits(),
            m.lift.to_bits(),
            m.leverage.to_bits(),
            m.conviction.to_bits(),
            m.zhang.to_bits(),
            m.jaccard.to_bits(),
            m.cosine.to_bits(),
            m.kulczynski.to_bits(),
            m.yule_q.to_bits(),
        ]
    }

    #[test]
    fn partial_row_codec_round_trips_bit_exactly() {
        let r = row(vec![3, 17], vec![42], 0.625);
        let rendered = "  {a,b} => {c} sup=0.625000 conf=0.312500 lift=1.8750";
        let line = encode_partial_row(&r, rendered);
        let (back, text) = decode_partial_row(&line).unwrap();
        assert_eq!(back.rule, r.rule);
        // Bit-exact across NaN and ∞, which `==` cannot check.
        assert_eq!(bits_of(&back.metrics), bits_of(&r.metrics));
        assert_eq!(text, rendered);
    }

    #[test]
    fn decode_rejects_malformed_rows() {
        for bad in [
            "no tag at all",
            "R 1|2 deadbeef\tmissing nine metric fields",
            "R 1,2 0000000000000000\tno side separator",
            "R 1|x 0,0,0,0,0,0,0,0,0,0\tbad id",
            "R 1|2 0,0,0,0,0,0,0,0,0,0,0\televen metrics",
        ] {
            assert!(decode_partial_row(bad).is_err(), "accepted: {bad}");
        }
        // Missing the rendered-text tab entirely.
        let r = row(vec![1], vec![2], 0.5);
        let line = encode_partial_row(&r, "text");
        let untabbed = line.replace('\t', " ");
        assert!(decode_partial_row(&untabbed).is_err());
    }

    #[test]
    fn parse_partial_reads_header_and_counts() {
        let r1 = row(vec![1], vec![2], 0.5);
        let r2 = row(vec![2], vec![3], 0.25);
        let resp = format!(
            "PARTIAL 2 gen=7 scanned=10 candidates=5 matched=2\n{}\n{}",
            encode_partial_row(&r1, "one"),
            encode_partial_row(&r2, "two"),
        );
        let frame = parse_partial(&resp).unwrap();
        assert_eq!(frame.generation, 7);
        assert_eq!(
            (frame.stats.scanned, frame.stats.candidates, frame.stats.matched),
            (10, 5, 2)
        );
        assert_eq!(frame.rows.len(), 2);
        assert_eq!(frame.rows[0].1, "one");

        // Row-count mismatch and non-PARTIAL responses are rejected.
        assert!(parse_partial("PARTIAL 3 gen=1 scanned=0 candidates=0 matched=0").is_err());
        assert!(parse_partial("RULES 0").is_err());
        assert!(parse_partial("PARTIAL 0 scanned=0 candidates=0 matched=0").is_err());
    }

    #[test]
    fn merge_imposes_total_order_independent_of_frame_split() {
        use crate::rules::metrics::Metric;
        // Rows with distinct supports; sort by support descending, limit 3.
        let rows: Vec<Row> = (1..=6)
            .map(|i| row(vec![i], vec![100 + i], f64::from(i) / 8.0))
            .collect();
        let sort = Some(SortSpec {
            metric: Metric::Support,
            descending: true,
        });
        let frame = |rs: &[Row], gen: u64| PartialFrame {
            generation: gen,
            stats: ExecStats::default(),
            rows: rs
                .iter()
                .map(|r| (r.clone(), format!("row-{}", r.metrics.support)))
                .collect(),
        };
        // Whole set in one frame vs split 2/4 in reversed order.
        let a = merge_rules_response(sort, Some(3), vec![frame(&rows, 1)], 0).unwrap();
        let b = merge_rules_response(
            sort,
            Some(3),
            vec![frame(&rows[2..], 1), frame(&rows[..2], 1)],
            0,
        )
        .unwrap();
        assert_eq!(a, b);
        let mut lines = a.lines();
        assert_eq!(lines.next(), Some("RULES 3"));
        assert_eq!(lines.next(), Some("row-0.75"));
        assert_eq!(lines.next(), Some("row-0.625"));
        assert_eq!(lines.next(), Some("row-0.5"));
        assert_eq!(lines.next(), None);
    }

    #[test]
    fn merge_flags_partial_and_rejects_mixed_generations() {
        let r = row(vec![1], vec![2], 0.5);
        let frame = |gen: u64| PartialFrame {
            generation: gen,
            stats: ExecStats::default(),
            rows: vec![(r.clone(), "the-row".to_string())],
        };
        let degraded = merge_rules_response(None, None, vec![frame(4)], 2).unwrap();
        assert!(degraded.starts_with("RULES 1 partial shards_down=2\n"));
        assert!(response_is_partial(&degraded));
        assert!(!response_is_partial("RULES 1\nthe-row"));
        assert!(merge_rules_response(None, None, vec![frame(4), frame(5)], 0).is_err());
    }

    #[test]
    fn merge_of_empty_frames_matches_single_node_empty_response() {
        let empty = PartialFrame {
            generation: 3,
            stats: ExecStats::default(),
            rows: Vec::new(),
        };
        assert_eq!(merge_rules_response(None, None, vec![empty], 0).unwrap(), "RULES 0");
    }

    #[test]
    fn cacheable_line_matches_service_policy() {
        assert!(cacheable_line("RULES WHERE conseq = x"));
        assert!(cacheable_line("FIND a => b"));
        assert!(cacheable_line("explain rules"));
        assert!(!cacheable_line("EXPLAIN ANALYZE RULES"));
        assert!(!cacheable_line("INGEST a,b"));
        assert!(!cacheable_line("STATS"));
        assert!(!cacheable_line(""));
    }

    #[test]
    fn fnv1a_spreads_distinct_lines() {
        // Not a distribution test — just that the hash actually varies.
        let hs: std::collections::HashSet<u64> = (0..64)
            .map(|i| fnv1a(&format!("FIND item{i} => other")))
            .collect();
        assert!(hs.len() > 60);
    }
}
