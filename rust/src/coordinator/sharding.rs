//! Shard routing and partial-count merging for the parallel mining stage.
//!
//! Transactions are hash-routed to worker shards; each shard accumulates
//! local item frequencies (and later local candidate counts), which the
//! leader merges. Routing is stable (same key, same shard) and the router
//! can rebalance by remapping shard slots to workers when worker counts
//! change mid-stream.

use crate::data::vocab::ItemId;

/// Stable hash router over `slots` virtual slots mapped onto `workers`.
#[derive(Debug, Clone)]
pub struct ShardRouter {
    /// slot -> worker assignment; remapped on rebalance.
    assignment: Vec<usize>,
    workers: usize,
}

impl ShardRouter {
    /// `slots` should exceed `workers` (virtual-slot rebalancing).
    pub fn new(workers: usize, slots: usize) -> Self {
        assert!(workers > 0 && slots >= workers);
        Self {
            assignment: (0..slots).map(|s| s % workers).collect(),
            workers,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn slots(&self) -> usize {
        self.assignment.len()
    }

    /// Route a transaction id to a worker.
    pub fn route(&self, tid: u64) -> usize {
        let slot = (tid.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % self.assignment.len();
        self.assignment[slot]
    }

    /// Rebalance onto a new worker count: every worker ends within ±1 slot
    /// of uniform, and no more slots move than that bound requires.
    ///
    /// Three linear passes, O(slots + workers·log workers). Pass 1 fixes
    /// per-worker retention quotas — `floor(slots/new_workers)` each, with
    /// the remainder slots granted to the workers currently holding the
    /// most (maximal retention ⇒ minimal movement). Pass 2 marks the
    /// retained slots: the first `quota[w]` occurrences of each valid
    /// worker stay put. Pass 3 assigns everything else (overflow plus
    /// slots on removed workers) to under-quota workers in index order.
    /// Marking *before* filling matters: a fused keep-or-fill walk lets
    /// foreign slots consume an overfull worker's quota early in the
    /// array and then evicts that worker's own later slots, breaking the
    /// minimal-movement bound. The even older single-pass version counted
    /// `moved` *while* iterating, so early slots of an overfull worker
    /// were counted as "already placed" and never migrated — a grow could
    /// leave the new workers underfull forever — and its inner scan was
    /// O(slots·workers).
    pub fn rebalance(&mut self, new_workers: usize) {
        assert!(new_workers > 0 && self.assignment.len() >= new_workers);
        let slots = self.assignment.len();
        let base = slots / new_workers;
        let extra = slots % new_workers;
        let mut counts = vec![0usize; new_workers];
        for &a in &self.assignment {
            if a < new_workers {
                counts[a] += 1;
            }
        }
        // Workers by current load, heaviest first (index breaks ties so
        // the result is deterministic): they get the `base + 1` quotas.
        let mut order: Vec<usize> = (0..new_workers).collect();
        order.sort_by(|&x, &y| counts[y].cmp(&counts[x]).then(x.cmp(&y)));
        let mut quota = vec![base; new_workers];
        for &w in order.iter().take(extra) {
            quota[w] += 1;
        }
        // Pass 2: each valid worker retains its first `quota` slots.
        let mut kept = vec![0usize; new_workers];
        let keep: Vec<bool> = self
            .assignment
            .iter()
            .map(|&a| {
                if a < new_workers && kept[a] < quota[a] {
                    kept[a] += 1;
                    true
                } else {
                    false
                }
            })
            .collect();
        // Pass 3: quotas sum to `slots` exactly, so `fill` never runs off
        // the end.
        let mut fill = 0usize;
        for (a, retained) in self.assignment.iter_mut().zip(&keep) {
            if *retained {
                continue;
            }
            while kept[fill] >= quota[fill] {
                fill += 1;
            }
            *a = fill;
            kept[fill] += 1;
        }
        self.workers = new_workers;
    }

    /// Fraction of slots assigned to each worker (balance diagnostics).
    pub fn load_shares(&self) -> Vec<f64> {
        let mut counts = vec![0usize; self.workers];
        for &a in &self.assignment {
            counts[a] += 1;
        }
        counts
            .into_iter()
            .map(|c| c as f64 / self.assignment.len() as f64)
            .collect()
    }
}

/// Per-shard partial item-frequency accumulator.
#[derive(Debug, Clone, Default)]
pub struct PartialCounts {
    pub freqs: Vec<u64>,
    pub transactions: usize,
}

impl PartialCounts {
    pub fn new(num_items: usize) -> Self {
        Self {
            freqs: vec![0; num_items],
            transactions: 0,
        }
    }

    pub fn observe(&mut self, tx: &[ItemId]) {
        self.transactions += 1;
        for &i in tx {
            if (i as usize) >= self.freqs.len() {
                self.freqs.resize(i as usize + 1, 0);
            }
            self.freqs[i as usize] += 1;
        }
    }

    /// Merge another shard's partials into this one.
    pub fn merge(&mut self, other: &PartialCounts) {
        if other.freqs.len() > self.freqs.len() {
            self.freqs.resize(other.freqs.len(), 0);
        }
        for (a, &b) in self.freqs.iter_mut().zip(&other.freqs) {
            *a += b;
        }
        self.transactions += other.transactions;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_stable() {
        let r = ShardRouter::new(4, 64);
        for tid in 0..1000u64 {
            assert_eq!(r.route(tid), r.route(tid));
            assert!(r.route(tid) < 4);
        }
    }

    #[test]
    fn routing_is_roughly_balanced() {
        let r = ShardRouter::new(4, 256);
        let mut counts = [0usize; 4];
        for tid in 0..100_000u64 {
            counts[r.route(tid)] += 1;
        }
        for &c in &counts {
            assert!((15_000..35_000).contains(&c), "imbalanced: {counts:?}");
        }
    }

    #[test]
    fn rebalance_shrink_covers_all_workers() {
        let mut r = ShardRouter::new(6, 60);
        r.rebalance(4);
        assert_eq!(r.workers(), 4);
        let shares = r.load_shares();
        assert_eq!(shares.len(), 4);
        for &s in &shares {
            assert!(s > 0.0);
        }
        for tid in 0..1000u64 {
            assert!(r.route(tid) < 4);
        }
    }

    #[test]
    fn rebalance_grow_uses_new_workers() {
        let mut r = ShardRouter::new(2, 64);
        r.rebalance(4);
        let shares = r.load_shares();
        assert_eq!(shares.len(), 4);
        assert!(shares[2] > 0.0 && shares[3] > 0.0, "{shares:?}");
    }

    #[test]
    fn rebalance_is_uniform_and_minimal_movement() {
        // Randomized worker-count walks: after every rebalance the load is
        // within ±1 slot of uniform and no more slots moved than the
        // information-theoretic floor plus one per worker.
        let mut seed = 0x9E37_79B9_7F4A_7C15u64;
        let mut next = move |bound: usize| {
            seed = seed
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            (seed >> 33) as usize % bound
        };
        for _ in 0..100 {
            let slots = 16 + next(240);
            let workers = 1 + next(8.min(slots));
            let mut r = ShardRouter::new(workers, slots);
            for _ in 0..6 {
                let new_workers = 1 + next(12.min(slots));
                let before = r.assignment.clone();
                r.rebalance(new_workers);

                let mut counts = vec![0usize; new_workers];
                for &a in &r.assignment {
                    assert!(a < new_workers);
                    counts[a] += 1;
                }
                let base = slots / new_workers;
                let extra = slots % new_workers;
                for &c in &counts {
                    assert!(
                        c == base || (extra > 0 && c == base + 1),
                        "non-uniform: slots={slots} workers={new_workers} counts={counts:?}"
                    );
                }

                let moved = before
                    .iter()
                    .zip(&r.assignment)
                    .filter(|(b, a)| b != a)
                    .count();
                // Exact minimality: a ±1-uniform result retains at most
                // min(count_before[w], quota[w]) slots per surviving
                // worker, and retention is maximized by granting the
                // `base + 1` quotas to the heaviest current holders (the
                // marginal slot is retained iff count_before > base).
                let mut before_counts = vec![0usize; new_workers];
                for &b in &before {
                    if b < new_workers {
                        before_counts[b] += 1;
                    }
                }
                let eligible = before_counts.iter().filter(|&&c| c > base).count();
                let best_retention: usize = before_counts
                    .iter()
                    .map(|&c| c.min(base))
                    .sum::<usize>()
                    + extra.min(eligible);
                let optimal = slots - best_retention;
                assert_eq!(
                    moved, optimal,
                    "moved {moved} != optimal {optimal} \
                     (slots={slots} workers={new_workers} before={before_counts:?})"
                );
            }
        }
    }

    #[test]
    fn rebalance_grow_migrates_early_slots_of_overfull_workers() {
        // Regression for the single-pass bug: growing 2 -> 4 must leave all
        // four workers within ±1 of uniform, including migrating slots that
        // appear *early* in the assignment vector.
        let mut r = ShardRouter::new(2, 64);
        r.rebalance(4);
        let mut counts = [0usize; 4];
        for &a in &r.assignment {
            counts[a] += 1;
        }
        assert_eq!(counts, [16, 16, 16, 16], "{counts:?}");
    }

    #[test]
    fn partial_counts_merge_equals_whole() {
        use crate::data::transaction::paper_example_db;
        let db = paper_example_db();
        let r = ShardRouter::new(3, 32);
        let mut parts: Vec<PartialCounts> =
            (0..3).map(|_| PartialCounts::new(db.num_items())).collect();
        for (tid, tx) in db.iter().enumerate() {
            parts[r.route(tid as u64)].observe(tx);
        }
        let mut merged = PartialCounts::new(db.num_items());
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.transactions, db.num_transactions());
        assert_eq!(merged.freqs, db.item_frequencies());
    }
}
