//! Shard routing and partial-count merging for the parallel mining stage.
//!
//! Transactions are hash-routed to worker shards; each shard accumulates
//! local item frequencies (and later local candidate counts), which the
//! leader merges. Routing is stable (same key, same shard) and the router
//! can rebalance by remapping shard slots to workers when worker counts
//! change mid-stream.

use crate::data::vocab::ItemId;

/// Stable hash router over `slots` virtual slots mapped onto `workers`.
#[derive(Debug, Clone)]
pub struct ShardRouter {
    /// slot -> worker assignment; remapped on rebalance.
    assignment: Vec<usize>,
    workers: usize,
}

impl ShardRouter {
    /// `slots` should exceed `workers` (virtual-slot rebalancing).
    pub fn new(workers: usize, slots: usize) -> Self {
        assert!(workers > 0 && slots >= workers);
        Self {
            assignment: (0..slots).map(|s| s % workers).collect(),
            workers,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn slots(&self) -> usize {
        self.assignment.len()
    }

    /// Route a transaction id to a worker.
    pub fn route(&self, tid: u64) -> usize {
        let slot = (tid.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % self.assignment.len();
        self.assignment[slot]
    }

    /// Rebalance onto a new worker count, moving as few slots as possible
    /// (slots keep their worker when still valid, excess is redistributed
    /// round-robin).
    pub fn rebalance(&mut self, new_workers: usize) {
        assert!(new_workers > 0 && self.assignment.len() >= new_workers);
        let mut next = 0usize;
        for a in &mut self.assignment {
            if *a >= new_workers {
                *a = next % new_workers;
                next += 1;
            }
        }
        // Growing: spread some slots onto the new workers.
        if new_workers > self.workers {
            let per = self.assignment.len() / new_workers;
            let mut moved = vec![0usize; new_workers];
            for a in &mut self.assignment {
                if moved[*a] >= per && *a < self.workers {
                    // candidate to move to an underfull new worker
                    if let Some(target) =
                        (self.workers..new_workers).find(|&w| moved[w] < per)
                    {
                        *a = target;
                    }
                }
                moved[*a] += 1;
            }
        }
        self.workers = new_workers;
    }

    /// Fraction of slots assigned to each worker (balance diagnostics).
    pub fn load_shares(&self) -> Vec<f64> {
        let mut counts = vec![0usize; self.workers];
        for &a in &self.assignment {
            counts[a] += 1;
        }
        counts
            .into_iter()
            .map(|c| c as f64 / self.assignment.len() as f64)
            .collect()
    }
}

/// Per-shard partial item-frequency accumulator.
#[derive(Debug, Clone, Default)]
pub struct PartialCounts {
    pub freqs: Vec<u64>,
    pub transactions: usize,
}

impl PartialCounts {
    pub fn new(num_items: usize) -> Self {
        Self {
            freqs: vec![0; num_items],
            transactions: 0,
        }
    }

    pub fn observe(&mut self, tx: &[ItemId]) {
        self.transactions += 1;
        for &i in tx {
            if (i as usize) >= self.freqs.len() {
                self.freqs.resize(i as usize + 1, 0);
            }
            self.freqs[i as usize] += 1;
        }
    }

    /// Merge another shard's partials into this one.
    pub fn merge(&mut self, other: &PartialCounts) {
        if other.freqs.len() > self.freqs.len() {
            self.freqs.resize(other.freqs.len(), 0);
        }
        for (a, &b) in self.freqs.iter_mut().zip(&other.freqs) {
            *a += b;
        }
        self.transactions += other.transactions;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_stable() {
        let r = ShardRouter::new(4, 64);
        for tid in 0..1000u64 {
            assert_eq!(r.route(tid), r.route(tid));
            assert!(r.route(tid) < 4);
        }
    }

    #[test]
    fn routing_is_roughly_balanced() {
        let r = ShardRouter::new(4, 256);
        let mut counts = [0usize; 4];
        for tid in 0..100_000u64 {
            counts[r.route(tid)] += 1;
        }
        for &c in &counts {
            assert!((15_000..35_000).contains(&c), "imbalanced: {counts:?}");
        }
    }

    #[test]
    fn rebalance_shrink_covers_all_workers() {
        let mut r = ShardRouter::new(6, 60);
        r.rebalance(4);
        assert_eq!(r.workers(), 4);
        let shares = r.load_shares();
        assert_eq!(shares.len(), 4);
        for &s in &shares {
            assert!(s > 0.0);
        }
        for tid in 0..1000u64 {
            assert!(r.route(tid) < 4);
        }
    }

    #[test]
    fn rebalance_grow_uses_new_workers() {
        let mut r = ShardRouter::new(2, 64);
        r.rebalance(4);
        let shares = r.load_shares();
        assert_eq!(shares.len(), 4);
        assert!(shares[2] > 0.0 && shares[3] > 0.0, "{shares:?}");
    }

    #[test]
    fn partial_counts_merge_equals_whole() {
        use crate::data::transaction::paper_example_db;
        let db = paper_example_db();
        let r = ShardRouter::new(3, 32);
        let mut parts: Vec<PartialCounts> =
            (0..3).map(|_| PartialCounts::new(db.num_items())).collect();
        for (tid, tx) in db.iter().enumerate() {
            parts[r.route(tid as u64)].observe(tx);
        }
        let mut merged = PartialCounts::new(db.num_items());
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.transactions, db.num_transactions());
        assert_eq!(merged.freqs, db.item_frequencies());
    }
}
