//! Pipeline configuration: defaults, `key=value` config files, and CLI
//! overrides (`clap` is not in the offline vendor set; the format is the
//! same one the launcher's `--set key=value` flags use).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::mining::MinerKind;

/// Which support-counting backend Apriori (and trie annotation) uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CounterKind {
    /// Rust-native vertical bitset intersection (default).
    Bitset,
    /// Horizontal per-transaction scan (textbook baseline).
    Horizontal,
    /// The AOT XLA artifact (L1 Pallas kernel via PJRT).
    Xla,
}

impl CounterKind {
    pub fn parse(s: &str) -> Option<CounterKind> {
        match s.to_ascii_lowercase().as_str() {
            "bitset" => Some(CounterKind::Bitset),
            "horizontal" => Some(CounterKind::Horizontal),
            "xla" => Some(CounterKind::Xla),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CounterKind::Bitset => "bitset",
            CounterKind::Horizontal => "horizontal",
            CounterKind::Xla => "xla",
        }
    }
}

/// Full pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Relative minimum support (paper's groceries setting: 0.005).
    pub minsup: f64,
    /// Minimum confidence for rule generation (0 keeps all).
    pub min_confidence: f64,
    pub miner: MinerKind,
    pub counter: CounterKind,
    /// Ingestion worker threads.
    pub workers: usize,
    /// Transactions per streamed chunk.
    pub chunk_size: usize,
    /// Bounded-queue capacity (chunks) between source and workers.
    pub queue_capacity: usize,
    /// Virtual shard slots for the router.
    pub shard_slots: usize,
    /// Degree of parallelism for the query executor (and the pipeline's
    /// overlapped build stages): 0 = auto (available cores, capped — see
    /// [`crate::query::parallel::default_query_threads`]), 1 = sequential.
    pub query_threads: usize,
    /// Incremental serving: auto-compact the delta into a fresh frozen
    /// snapshot once this many ingested transactions are pending
    /// (`--compact-threshold`; 0 = compact only on explicit `COMPACT`).
    pub compact_threshold: usize,
    /// JSONL telemetry destination (`--telemetry-out <path>`; None = no
    /// export). Build-stage and serving records stream here through the
    /// background [`crate::obs::export::TelemetryExporter`].
    pub telemetry_out: Option<String>,
    /// Event-loop shards for the nonblocking TCP front end
    /// (`--service-shards`; 0 = auto — available cores, capped; see
    /// [`crate::coordinator::frontend::default_service_shards`]).
    pub service_shards: usize,
    /// Admission-control bound on in-flight service requests; requests
    /// beyond it are answered `BUSY` (`--max-pending`).
    pub max_pending: usize,
    /// Evict a service connection after this many seconds of inactivity
    /// (`--idle-timeout-s`; 0 = never).
    pub idle_timeout_s: usize,
    /// Generation-keyed query-result cache size in MiB
    /// (`--result-cache-mb`; 0 = off).
    pub result_cache_mb: usize,
    /// Durability directory (`--wal-dir <path>`; None = no crash-safety
    /// plane). Holds the write-ahead log, atomic checkpoints, and the
    /// MANIFEST recovery pointer (DESIGN.md §16).
    pub wal_dir: Option<String>,
    /// WAL fsync policy: `always`, `never`, or `batch:N`
    /// (`--wal-fsync`; parsed by
    /// [`crate::coordinator::wal::FsyncPolicy::parse`]).
    pub wal_fsync: String,
    /// Scatter-gather shard identity `k/n` (`--shard-of`; None =
    /// standalone). A shard refuses `SCATTER` requests addressed to a
    /// different partition and appends ` shard=k/n` to STATS
    /// (DESIGN.md §18).
    pub shard_of: Option<(usize, usize)>,
    /// Scatter-gather coordinator mode: comma-separated shard addresses
    /// in partition order (`--shards host:port,...`; None = serve
    /// locally). Mutually exclusive with `shard_of`.
    pub shards: Option<String>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            minsup: 0.005,
            min_confidence: 0.0,
            miner: MinerKind::Apriori,
            counter: CounterKind::Bitset,
            workers: 4,
            chunk_size: 512,
            queue_capacity: 16,
            shard_slots: 64,
            query_threads: 0,
            compact_threshold: 0,
            telemetry_out: None,
            service_shards: 0,
            max_pending: 1024,
            idle_timeout_s: 0,
            result_cache_mb: 0,
            wal_dir: None,
            wal_fsync: "always".to_string(),
            shard_of: None,
            shards: None,
        }
    }
}

impl PipelineConfig {
    /// Apply one `key=value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "minsup" => self.minsup = parse_f64_in(value, 0.0, 1.0)?,
            "min_confidence" | "minconf" => self.min_confidence = parse_f64_in(value, 0.0, 1.0)?,
            "miner" => {
                self.miner = MinerKind::parse(value)
                    .with_context(|| format!("unknown miner `{value}`"))?
            }
            "counter" => {
                self.counter = CounterKind::parse(value)
                    .with_context(|| format!("unknown counter `{value}`"))?
            }
            "workers" => self.workers = parse_usize_min(value, 1)?,
            "chunk_size" => self.chunk_size = parse_usize_min(value, 1)?,
            "queue_capacity" => self.queue_capacity = parse_usize_min(value, 1)?,
            "shard_slots" => self.shard_slots = parse_usize_min(value, 1)?,
            "query_threads" => self.query_threads = parse_usize_min(value, 0)?,
            "compact_threshold" => self.compact_threshold = parse_usize_min(value, 0)?,
            "telemetry_out" => {
                anyhow::ensure!(!value.is_empty(), "telemetry_out needs a path");
                self.telemetry_out = Some(value.to_string());
            }
            "service_shards" => self.service_shards = parse_usize_min(value, 0)?,
            "max_pending" => self.max_pending = parse_usize_min(value, 1)?,
            "idle_timeout_s" => self.idle_timeout_s = parse_usize_min(value, 0)?,
            "result_cache_mb" => self.result_cache_mb = parse_usize_min(value, 0)?,
            "wal_dir" => {
                anyhow::ensure!(!value.is_empty(), "wal_dir needs a path");
                self.wal_dir = Some(value.to_string());
            }
            "wal_fsync" => {
                crate::coordinator::wal::FsyncPolicy::parse(value)?;
                self.wal_fsync = value.to_string();
            }
            "shard_of" => self.shard_of = Some(parse_shard_of(value)?),
            "shards" => {
                anyhow::ensure!(
                    value.split(',').all(|a| !a.trim().is_empty()),
                    "shards needs a comma-separated, gap-free address list"
                );
                self.shards = Some(value.to_string());
            }
            other => bail!("unknown config key `{other}`"),
        }
        Ok(())
    }

    /// Load a `key=value` file (# comments, blank lines ignored).
    pub fn load(path: &Path) -> Result<PipelineConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        let mut cfg = PipelineConfig::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key=value", lineno + 1))?;
            cfg.set(k.trim(), v.trim())
                .with_context(|| format!("line {}", lineno + 1))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.shard_slots >= self.workers, "shard_slots < workers");
        anyhow::ensure!(
            self.shard_of.is_none() || self.shards.is_none(),
            "shard_of and shards are mutually exclusive (a process is a \
             shard or a coordinator, not both)"
        );
        anyhow::ensure!(
            self.miner == MinerKind::Apriori || self.counter != CounterKind::Xla,
            "counter=xla requires miner=apriori (the XLA backend plugs into the \
             level-wise counting step)"
        );
        Ok(())
    }

    /// Effective query-executor parallelism: the configured degree, or the
    /// auto default (available cores, capped) when 0.
    pub fn effective_query_threads(&self) -> usize {
        if self.query_threads == 0 {
            crate::query::parallel::default_query_threads()
        } else {
            self.query_threads
        }
    }

    /// Render as a `key=value` block (round-trips through `load`).
    pub fn render(&self) -> String {
        let mut out = format!(
            "minsup={}\nmin_confidence={}\nminer={}\ncounter={}\nworkers={}\nchunk_size={}\nqueue_capacity={}\nshard_slots={}\nquery_threads={}\ncompact_threshold={}\nservice_shards={}\nmax_pending={}\nidle_timeout_s={}\nresult_cache_mb={}\n",
            self.minsup,
            self.min_confidence,
            self.miner.name(),
            self.counter.name(),
            self.workers,
            self.chunk_size,
            self.queue_capacity,
            self.shard_slots,
            self.query_threads,
            self.compact_threshold,
            self.service_shards,
            self.max_pending,
            self.idle_timeout_s,
            self.result_cache_mb
        );
        if let Some(path) = &self.telemetry_out {
            out.push_str(&format!("telemetry_out={path}\n"));
        }
        out.push_str(&format!("wal_fsync={}\n", self.wal_fsync));
        if let Some(dir) = &self.wal_dir {
            out.push_str(&format!("wal_dir={dir}\n"));
        }
        if let Some((k, n)) = self.shard_of {
            out.push_str(&format!("shard_of={k}/{n}\n"));
        }
        if let Some(shards) = &self.shards {
            out.push_str(&format!("shards={shards}\n"));
        }
        out
    }

    /// Parsed WAL fsync policy (validated at `set` time, so this cannot
    /// fail on a config that went through [`PipelineConfig::set`]/`load`).
    pub fn wal_fsync_policy(&self) -> crate::coordinator::wal::FsyncPolicy {
        crate::coordinator::wal::FsyncPolicy::parse(&self.wal_fsync)
            .expect("wal_fsync validated on set")
    }
}

fn parse_f64_in(value: &str, lo: f64, hi: f64) -> Result<f64> {
    let v: f64 = value.parse().with_context(|| format!("bad float `{value}`"))?;
    anyhow::ensure!((lo..=hi).contains(&v), "value {v} outside [{lo}, {hi}]");
    Ok(v)
}

fn parse_usize_min(value: &str, min: usize) -> Result<usize> {
    let v: usize = value.parse().with_context(|| format!("bad integer `{value}`"))?;
    anyhow::ensure!(v >= min, "value {v} below minimum {min}");
    Ok(v)
}

/// Parse a `k/n` shard identity; `k < n`, `n > 0`.
pub fn parse_shard_of(value: &str) -> Result<(usize, usize)> {
    let (k, n) = value
        .split_once('/')
        .with_context(|| format!("bad shard identity `{value}` (expected k/n)"))?;
    let k: usize = k.trim().parse().with_context(|| format!("bad shard index `{k}`"))?;
    let n: usize = n.trim().parse().with_context(|| format!("bad shard count `{n}`"))?;
    anyhow::ensure!(n > 0 && k < n, "shard {k}/{n} out of range");
    Ok((k, n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        PipelineConfig::default().validate().unwrap();
    }

    #[test]
    fn set_overrides() {
        let mut c = PipelineConfig::default();
        c.set("minsup", "0.01").unwrap();
        c.set("miner", "fpgrowth").unwrap();
        c.set("counter", "horizontal").unwrap();
        c.set("workers", "8").unwrap();
        assert_eq!(c.minsup, 0.01);
        assert_eq!(c.miner, MinerKind::FpGrowth);
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("minsup", "1.5").is_err());
        assert!(c.set("workers", "0").is_err());
    }

    #[test]
    fn query_threads_zero_means_auto() {
        let mut c = PipelineConfig::default();
        assert_eq!(c.query_threads, 0);
        assert!(c.effective_query_threads() >= 1);
        c.set("query_threads", "3").unwrap();
        assert_eq!(c.effective_query_threads(), 3);
        assert!(c.set("query_threads", "nope").is_err());
        // Round-trips through render/load like every other key.
        assert!(c.render().contains("query_threads=3"), "{}", c.render());
    }

    #[test]
    fn compact_threshold_roundtrips() {
        let mut c = PipelineConfig::default();
        assert_eq!(c.compact_threshold, 0);
        c.set("compact_threshold", "256").unwrap();
        assert_eq!(c.compact_threshold, 256);
        assert!(c.render().contains("compact_threshold=256"), "{}", c.render());
        assert!(c.set("compact_threshold", "nope").is_err());
    }

    #[test]
    fn telemetry_out_roundtrips() {
        let mut c = PipelineConfig::default();
        assert!(c.telemetry_out.is_none());
        assert!(!c.render().contains("telemetry_out="), "{}", c.render());
        c.set("telemetry_out", "artifacts/telemetry.jsonl").unwrap();
        assert_eq!(c.telemetry_out.as_deref(), Some("artifacts/telemetry.jsonl"));
        assert!(
            c.render().contains("telemetry_out=artifacts/telemetry.jsonl"),
            "{}",
            c.render()
        );
        assert!(c.set("telemetry_out", "").is_err());
        // Round-trips through a config file like every other key.
        let dir = std::env::temp_dir().join(format!("tor_cfg_tel_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pipeline.cfg");
        std::fs::write(&path, c.render()).unwrap();
        let back = PipelineConfig::load(&path).unwrap();
        assert_eq!(back.telemetry_out.as_deref(), Some("artifacts/telemetry.jsonl"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn service_keys_roundtrip() {
        let mut c = PipelineConfig::default();
        assert_eq!(c.service_shards, 0);
        assert_eq!(c.max_pending, 1024);
        assert_eq!(c.idle_timeout_s, 0);
        assert_eq!(c.result_cache_mb, 0);
        c.set("service_shards", "4").unwrap();
        c.set("max_pending", "64").unwrap();
        c.set("idle_timeout_s", "30").unwrap();
        c.set("result_cache_mb", "16").unwrap();
        assert!(c.set("max_pending", "0").is_err(), "pending bound needs >=1");
        assert!(c.set("service_shards", "nope").is_err());
        let dir = std::env::temp_dir().join(format!("tor_cfg_svc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pipeline.cfg");
        std::fs::write(&path, c.render()).unwrap();
        let back = PipelineConfig::load(&path).unwrap();
        assert_eq!(back.service_shards, 4);
        assert_eq!(back.max_pending, 64);
        assert_eq!(back.idle_timeout_s, 30);
        assert_eq!(back.result_cache_mb, 16);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wal_keys_roundtrip() {
        let mut c = PipelineConfig::default();
        assert!(c.wal_dir.is_none());
        assert_eq!(c.wal_fsync, "always");
        assert!(!c.render().contains("wal_dir="), "{}", c.render());
        c.set("wal_dir", "artifacts/wal").unwrap();
        c.set("wal_fsync", "batch:8").unwrap();
        assert_eq!(
            c.wal_fsync_policy(),
            crate::coordinator::wal::FsyncPolicy::Batch(8)
        );
        assert!(c.set("wal_dir", "").is_err());
        assert!(c.set("wal_fsync", "sometimes").is_err());
        assert!(c.set("wal_fsync", "batch:0").is_err());
        let dir = std::env::temp_dir().join(format!("tor_cfg_wal_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pipeline.cfg");
        std::fs::write(&path, c.render()).unwrap();
        let back = PipelineConfig::load(&path).unwrap();
        assert_eq!(back.wal_dir.as_deref(), Some("artifacts/wal"));
        assert_eq!(back.wal_fsync, "batch:8");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_keys_roundtrip_and_exclude_each_other() {
        let mut c = PipelineConfig::default();
        assert!(c.shard_of.is_none() && c.shards.is_none());
        assert!(!c.render().contains("shard"), "{}", c.render());
        c.set("shard_of", "1/4").unwrap();
        assert_eq!(c.shard_of, Some((1, 4)));
        assert!(c.set("shard_of", "4/4").is_err());
        assert!(c.set("shard_of", "0/0").is_err());
        assert!(c.set("shard_of", "1-4").is_err());
        c.validate().unwrap();
        // A process cannot be both a shard and a coordinator.
        c.set("shards", "127.0.0.1:7000,127.0.0.1:7001").unwrap();
        assert!(c.validate().is_err());
        c.shard_of = None;
        c.validate().unwrap();
        assert!(c.set("shards", "a:1,,b:2").is_err(), "gap in the shard list");
        let dir = std::env::temp_dir().join(format!("tor_cfg_shard_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pipeline.cfg");
        std::fs::write(&path, c.render()).unwrap();
        let back = PipelineConfig::load(&path).unwrap();
        assert_eq!(back.shards.as_deref(), Some("127.0.0.1:7000,127.0.0.1:7001"));
        let mut shard = PipelineConfig::default();
        shard.set("shard_of", "3/8").unwrap();
        std::fs::write(&path, shard.render()).unwrap();
        assert_eq!(PipelineConfig::load(&path).unwrap().shard_of, Some((3, 8)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn xla_requires_apriori() {
        let mut c = PipelineConfig::default();
        c.set("counter", "xla").unwrap();
        c.set("miner", "eclat").unwrap();
        assert!(c.validate().is_err());
        c.set("miner", "apriori").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn render_load_roundtrip() {
        let mut c = PipelineConfig::default();
        c.set("minsup", "0.02").unwrap();
        c.set("miner", "fpmax").unwrap();
        let dir = std::env::temp_dir().join(format!("tor_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pipeline.cfg");
        std::fs::write(&path, c.render()).unwrap();
        let back = PipelineConfig::load(&path).unwrap();
        assert_eq!(back.minsup, 0.02);
        assert_eq!(back.miner, MinerKind::FpMax);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("tor_cfg_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.cfg");
        std::fs::write(&path, "minsup 0.1\n").unwrap();
        assert!(PipelineConfig::load(&path).is_err());
        std::fs::write(&path, "# comment\n\nminsup=0.1\n").unwrap();
        assert!(PipelineConfig::load(&path).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
