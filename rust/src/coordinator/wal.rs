//! Write-ahead log for the incremental serving path (DESIGN.md §16).
//!
//! Every INGEST batch is appended — checksummed, length-prefixed,
//! epoch-tagged, sequence-numbered — *before* the mutation is applied or
//! acknowledged, so a crash can lose at most writes the configured fsync
//! policy had not yet made durable, and can never surface a partially
//! applied batch. COMPACT appends a marker record, then the durability
//! plane checkpoints and starts a fresh log (truncation).
//!
//! On-disk layout, little-endian:
//!
//! ```text
//! header:  magic "TORW" | version u32 (= 1) | start_seq u64 | crc32 u32
//! record:  len u32 | crc32 u32 (over payload) | payload
//! payload: seq u64 | epoch u64 | kind u8 | body
//!   kind 1 = INGEST: num_tx u32 | per tx: len u32, item ids u32…
//!   kind 2 = COMPACT (empty body)
//! ```
//!
//! The reader is torn-tail tolerant: it stops at the first frame whose
//! length prefix, checksum, sequence number, or body fails to parse —
//! exactly the suffix an interrupted append can leave — and returns every
//! record before it. The header itself is always valid because log
//! creation goes through write-temp + fsync + atomic rename.
//!
//! Recovery never appends to a survived log: a torn partial frame may sit
//! beyond the last whole record, and anything written after that garbage
//! would be unreadable (the reader stops at the torn frame). Instead the
//! still-needed tail is rewritten into a fresh log ([`Wal::rewrite`],
//! again temp + fsync + rename), so pre-crash garbage can never shadow
//! records acknowledged after recovery.

use std::fmt;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::util::crc32::crc32;
use crate::util::fsio::{self, Vfs, VfsFile};

const WAL_MAGIC: [u8; 4] = *b"TORW";
const WAL_VERSION: u32 = 1;
const KIND_INGEST: u8 = 1;
const KIND_COMPACT: u8 = 2;
/// seq u64 + epoch u64 + kind u8.
const PAYLOAD_MIN: usize = 17;
const FRAME_MAX: usize = 1 << 28;

/// When appended records are made durable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync after every append: an acknowledged INGEST survives any
    /// crash (the chaos harness's strongest oracle).
    Always,
    /// fsync every N appends: bounded loss window of < N acknowledged
    /// batches.
    Batch(u32),
    /// Never fsync from the append path (OS flushes on its schedule;
    /// shutdown still syncs). Fastest, weakest.
    Never,
}

impl FsyncPolicy {
    /// Parse `always` / `never` / `batch:N`.
    pub fn parse(s: &str) -> Result<FsyncPolicy> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "never" => Ok(FsyncPolicy::Never),
            _ => {
                if let Some(n) = s.strip_prefix("batch:") {
                    let n: u32 = n
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad batch size in wal_fsync '{s}'"))?;
                    anyhow::ensure!(n >= 1, "wal_fsync batch size must be >= 1");
                    Ok(FsyncPolicy::Batch(n))
                } else {
                    anyhow::bail!("wal_fsync must be always, never, or batch:N (got '{s}')")
                }
            }
        }
    }
}

impl fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsyncPolicy::Always => write!(f, "always"),
            FsyncPolicy::Batch(n) => write!(f, "batch:{n}"),
            FsyncPolicy::Never => write!(f, "never"),
        }
    }
}

/// A logged mutation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalOp {
    /// One INGEST batch, transactions exactly as submitted.
    Ingest(Vec<Vec<u32>>),
    /// A compaction barrier (the checkpoint it pairs with supersedes
    /// everything at or before this record's sequence number).
    Compact,
}

/// A decoded log record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WalRecord {
    pub seq: u64,
    pub epoch: u64,
    pub op: WalOp,
}

/// Append handle over one log file.
pub struct Wal {
    vfs: Arc<dyn Vfs>,
    path: PathBuf,
    file: Box<dyn VfsFile>,
    policy: FsyncPolicy,
    unsynced: u32,
    next_seq: u64,
    appended: u64,
}

impl Wal {
    /// Start a fresh log whose first record will carry `start_seq`. The
    /// header is written atomically (temp + fsync + rename), replacing
    /// any previous log at `path` — this is how COMPACT truncates.
    pub fn create(
        vfs: Arc<dyn Vfs>,
        path: &Path,
        policy: FsyncPolicy,
        start_seq: u64,
    ) -> Result<Wal> {
        let mut header = Vec::with_capacity(20);
        header.extend_from_slice(&WAL_MAGIC);
        header.extend_from_slice(&WAL_VERSION.to_le_bytes());
        header.extend_from_slice(&start_seq.to_le_bytes());
        let crc = crc32(&header);
        header.extend_from_slice(&crc.to_le_bytes());
        fsio::atomic_write_with(vfs.as_ref(), path, |w| w.write_all(&header))
            .with_context(|| format!("create wal {}", path.display()))?;
        let file = vfs
            .open_append(path)
            .with_context(|| format!("open wal {} for append", path.display()))?;
        Ok(Wal {
            vfs,
            path: path.to_path_buf(),
            file,
            policy,
            unsynced: 0,
            next_seq: start_seq,
            appended: 0,
        })
    }

    /// Atomically rewrite the log to contain exactly `records` (which
    /// must be sequence-contiguous from `start_seq`) and open it for
    /// appending. Recovery uses this instead of reopening the survived
    /// file so a torn partial frame the crash left beyond the last whole
    /// record can never shadow records appended afterwards (see the
    /// module docs). The rename either keeps the old complete log or
    /// installs the new complete one — every still-needed record stays
    /// durable at all times.
    pub fn rewrite(
        vfs: Arc<dyn Vfs>,
        path: &Path,
        policy: FsyncPolicy,
        start_seq: u64,
        records: &[WalRecord],
    ) -> Result<Wal> {
        let mut bytes = Vec::with_capacity(20 + records.len() * 32);
        bytes.extend_from_slice(&WAL_MAGIC);
        bytes.extend_from_slice(&WAL_VERSION.to_le_bytes());
        bytes.extend_from_slice(&start_seq.to_le_bytes());
        let crc = crc32(&bytes);
        bytes.extend_from_slice(&crc.to_le_bytes());
        for (i, rec) in records.iter().enumerate() {
            debug_assert_eq!(rec.seq, start_seq + i as u64, "rewrite records not contiguous");
            bytes.extend_from_slice(&encode_frame(rec.seq, rec.epoch, &rec.op));
        }
        fsio::atomic_write_with(vfs.as_ref(), path, |w| w.write_all(&bytes))
            .with_context(|| format!("rewrite wal {}", path.display()))?;
        let file = vfs
            .open_append(path)
            .with_context(|| format!("open wal {} for append", path.display()))?;
        Ok(Wal {
            vfs,
            path: path.to_path_buf(),
            file,
            policy,
            unsynced: 0,
            next_seq: start_seq + records.len() as u64,
            appended: 0,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn policy(&self) -> FsyncPolicy {
        self.policy
    }

    /// Sequence number the next append will use.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Records appended through this handle.
    pub fn appended(&self) -> u64 {
        self.appended
    }

    /// Append one record and apply the fsync policy. Returns the record's
    /// sequence number. On error the log must be considered failed: the
    /// caller (durability plane) flips to degraded mode.
    pub fn append(&mut self, epoch: u64, op: &WalOp) -> Result<u64> {
        let seq = self.next_seq;
        let frame = encode_frame(seq, epoch, op);
        self.file
            .write_all(&frame)
            .with_context(|| format!("append to wal {}", self.path.display()))?;
        match self.policy {
            FsyncPolicy::Always => self.sync()?,
            FsyncPolicy::Batch(n) => {
                self.unsynced += 1;
                if self.unsynced >= n {
                    self.sync()?;
                }
            }
            FsyncPolicy::Never => {}
        }
        self.next_seq = seq + 1;
        self.appended += 1;
        Ok(seq)
    }

    /// Force everything appended so far to durable storage (shutdown
    /// drain and the `batch` policy threshold both land here).
    pub fn sync(&mut self) -> Result<()> {
        self.file
            .sync_all()
            .with_context(|| format!("fsync wal {}", self.path.display()))?;
        self.unsynced = 0;
        Ok(())
    }

    /// Replace this log with a fresh one continuing the sequence — the
    /// COMPACT-time truncation.
    pub fn truncate(&mut self) -> Result<()> {
        let fresh = Wal::create(
            Arc::clone(&self.vfs),
            &self.path,
            self.policy,
            self.next_seq,
        )?;
        let appended = self.appended;
        *self = fresh;
        self.appended = appended;
        Ok(())
    }
}

/// Read a log: `(start_seq, records)`. Torn-tail tolerant (see module
/// docs); errors only on a missing/unreadable file or corrupt header.
pub fn read_wal(vfs: &dyn Vfs, path: &Path) -> Result<(u64, Vec<WalRecord>)> {
    let bytes = vfs
        .read(path)
        .with_context(|| format!("read wal {}", path.display()))?;
    anyhow::ensure!(bytes.len() >= 20, "wal header truncated");
    anyhow::ensure!(bytes[..4] == WAL_MAGIC, "wal bad magic");
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    anyhow::ensure!(version == WAL_VERSION, "wal unsupported version {version}");
    let stored = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
    anyhow::ensure!(stored == crc32(&bytes[..16]), "wal header checksum mismatch");
    let start_seq = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let mut records = Vec::new();
    let mut pos = 20usize;
    let mut expect_seq = start_seq;
    while bytes.len() - pos >= 8 {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if len < PAYLOAD_MIN || len > FRAME_MAX || bytes.len() - pos - 8 < len {
            break; // torn or garbage tail
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            break;
        }
        let Some(rec) = decode_payload(payload) else {
            break;
        };
        if rec.seq != expect_seq {
            break;
        }
        expect_seq += 1;
        pos += 8 + len;
        records.push(rec);
    }
    Ok((start_seq, records))
}

/// Encode one record as its on-disk frame: `len | crc | payload`.
fn encode_frame(seq: u64, epoch: u64, op: &WalOp) -> Vec<u8> {
    let mut payload = Vec::with_capacity(PAYLOAD_MIN);
    payload.extend_from_slice(&seq.to_le_bytes());
    payload.extend_from_slice(&epoch.to_le_bytes());
    match op {
        WalOp::Ingest(txs) => {
            payload.push(KIND_INGEST);
            payload.extend_from_slice(&(txs.len() as u32).to_le_bytes());
            for tx in txs {
                payload.extend_from_slice(&(tx.len() as u32).to_le_bytes());
                for &it in tx {
                    payload.extend_from_slice(&it.to_le_bytes());
                }
            }
        }
        WalOp::Compact => payload.push(KIND_COMPACT),
    }
    let mut frame = Vec::with_capacity(payload.len() + 8);
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&crc32(&payload).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame
}

fn decode_payload(p: &[u8]) -> Option<WalRecord> {
    if p.len() < PAYLOAD_MIN {
        return None;
    }
    let seq = u64::from_le_bytes(p[0..8].try_into().ok()?);
    let epoch = u64::from_le_bytes(p[8..16].try_into().ok()?);
    let kind = p[16];
    let body = &p[17..];
    let op = match kind {
        KIND_COMPACT => {
            if !body.is_empty() {
                return None;
            }
            WalOp::Compact
        }
        KIND_INGEST => {
            let mut pos = 0usize;
            let num_tx = read_u32_at(body, &mut pos)? as usize;
            let mut txs = Vec::with_capacity(num_tx.min(1 << 16));
            for _ in 0..num_tx {
                let len = read_u32_at(body, &mut pos)? as usize;
                let mut tx = Vec::with_capacity(len.min(1 << 16));
                for _ in 0..len {
                    tx.push(read_u32_at(body, &mut pos)?);
                }
                txs.push(tx);
            }
            if pos != body.len() {
                return None;
            }
            WalOp::Ingest(txs)
        }
        _ => return None,
    };
    Some(WalRecord { seq, epoch, op })
}

fn read_u32_at(b: &[u8], pos: &mut usize) -> Option<u32> {
    if b.len() - *pos < 4 {
        return None;
    }
    let v = u32::from_le_bytes([b[*pos], b[*pos + 1], b[*pos + 2], b[*pos + 3]]);
    *pos += 4;
    Some(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fsio::MemVfs;

    fn sample_ops() -> Vec<(u64, WalOp)> {
        vec![
            (0, WalOp::Ingest(vec![vec![1, 2, 3], vec![4]])),
            (0, WalOp::Ingest(vec![vec![7]])),
            (0, WalOp::Compact),
            (1, WalOp::Ingest(vec![vec![], vec![2, 2, 9]])),
        ]
    }

    #[test]
    fn roundtrip_all_record_kinds() {
        let vfs: Arc<dyn Vfs> = Arc::new(MemVfs::new(1));
        let path = Path::new("wal.log");
        let mut wal = Wal::create(Arc::clone(&vfs), path, FsyncPolicy::Always, 5).unwrap();
        for (epoch, op) in sample_ops() {
            wal.append(epoch, &op).unwrap();
        }
        assert_eq!(wal.next_seq(), 9);
        let (start, recs) = read_wal(vfs.as_ref(), path).unwrap();
        assert_eq!(start, 5);
        assert_eq!(recs.len(), 4);
        for (i, ((epoch, op), rec)) in sample_ops().iter().zip(&recs).enumerate() {
            assert_eq!(rec.seq, 5 + i as u64);
            assert_eq!(rec.epoch, *epoch);
            assert_eq!(&rec.op, op);
        }
    }

    #[test]
    fn truncation_at_every_offset_yields_a_record_prefix() {
        let vfs = MemVfs::new(2);
        let varc: Arc<dyn Vfs> = Arc::new(vfs.clone());
        let path = Path::new("wal.log");
        let mut wal = Wal::create(varc, path, FsyncPolicy::Never, 0).unwrap();
        for (epoch, op) in sample_ops() {
            wal.append(epoch, &op).unwrap();
        }
        let full = vfs.read(path).unwrap();
        let (_, all) = read_wal(&vfs, path).unwrap();
        for cut in 20..full.len() {
            let t = MemVfs::new(3);
            let mut f = t.create(path).unwrap();
            f.write_all(&full[..cut]).unwrap();
            drop(f);
            let (start, recs) = read_wal(&t, path).unwrap();
            assert_eq!(start, 0);
            assert!(recs.len() <= all.len());
            assert_eq!(recs[..], all[..recs.len()], "cut at {cut}");
        }
        // Cutting into the header is a hard error, not silent emptiness.
        for cut in 0..20 {
            let t = MemVfs::new(4);
            let mut f = t.create(path).unwrap();
            f.write_all(&full[..cut]).unwrap();
            drop(f);
            assert!(read_wal(&t, path).is_err(), "header cut {cut} accepted");
        }
    }

    #[test]
    fn bit_flips_never_yield_phantom_records() {
        let vfs = MemVfs::new(5);
        let varc: Arc<dyn Vfs> = Arc::new(vfs.clone());
        let path = Path::new("wal.log");
        let mut wal = Wal::create(varc, path, FsyncPolicy::Never, 0).unwrap();
        for (epoch, op) in sample_ops() {
            wal.append(epoch, &op).unwrap();
        }
        let full = vfs.read(path).unwrap();
        let (_, all) = read_wal(&vfs, path).unwrap();
        for byte in 20..full.len() {
            let mut bytes = full.clone();
            bytes[byte] ^= 0x10;
            let t = MemVfs::new(6);
            let mut f = t.create(path).unwrap();
            f.write_all(&bytes).unwrap();
            drop(f);
            let (_, recs) = read_wal(&t, path).unwrap();
            // Every surviving record is a genuine prefix record.
            assert!(recs.len() < all.len(), "flip at {byte} kept all records");
            assert_eq!(recs[..], all[..recs.len()], "flip at {byte}");
        }
    }

    #[test]
    fn truncate_restarts_the_sequence_where_it_left_off() {
        let vfs: Arc<dyn Vfs> = Arc::new(MemVfs::new(7));
        let path = Path::new("wal.log");
        let mut wal = Wal::create(Arc::clone(&vfs), path, FsyncPolicy::Always, 0).unwrap();
        for (epoch, op) in sample_ops() {
            wal.append(epoch, &op).unwrap();
        }
        wal.truncate().unwrap();
        assert_eq!(wal.next_seq(), 4);
        wal.append(9, &WalOp::Compact).unwrap();
        let (start, recs) = read_wal(vfs.as_ref(), path).unwrap();
        assert_eq!(start, 4);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].seq, 4);
        assert_eq!(recs[0].epoch, 9);
    }

    #[test]
    fn unsynced_tail_is_lost_cleanly_on_crash() {
        for seed in 0..24u64 {
            let vfs = MemVfs::new(seed);
            let varc: Arc<dyn Vfs> = Arc::new(vfs.clone());
            let path = Path::new("wal.log");
            let mut wal = Wal::create(varc, path, FsyncPolicy::Batch(2), 0).unwrap();
            for (epoch, op) in sample_ops() {
                wal.append(epoch, &op).unwrap();
            }
            // 4 records, batch:2 → records 0..4 synced in pairs; append a
            // 5th that stays unsynced.
            wal.append(3, &WalOp::Compact).unwrap();
            vfs.crash_now();
            vfs.recover();
            let (_, recs) = read_wal(&vfs, path).unwrap();
            assert!(recs.len() >= 4, "synced records lost (seed {seed})");
            assert!(recs.len() <= 5);
            for (i, rec) in recs.iter().enumerate() {
                assert_eq!(rec.seq, i as u64);
            }
        }
    }

    #[test]
    fn rewrite_discards_torn_garbage_and_preserves_the_tail() {
        let vfs = MemVfs::new(11);
        let varc: Arc<dyn Vfs> = Arc::new(vfs.clone());
        let path = Path::new("wal.log");
        let mut wal = Wal::create(Arc::clone(&varc), path, FsyncPolicy::Always, 4).unwrap();
        for (epoch, op) in sample_ops() {
            wal.append(epoch, &op).unwrap();
        }
        drop(wal);
        // Simulate the torn tail a crash leaves: half a frame of garbage.
        let mut f = vfs.open_append(path).unwrap();
        f.write_all(&[0xAB; 13]).unwrap();
        f.sync_all().unwrap();
        drop(f);
        let (_, recs) = read_wal(&vfs, path).unwrap();
        assert_eq!(recs.len(), 4);
        // Keep the last two records (what recovery does for the pending
        // tail), then append: the new record must stay readable.
        let tail = recs[2..].to_vec();
        let mut wal = Wal::rewrite(Arc::clone(&varc), path, FsyncPolicy::Always, 6, &tail).unwrap();
        assert_eq!(wal.next_seq(), 8);
        wal.append(2, &WalOp::Ingest(vec![vec![42]])).unwrap();
        let (start, recs) = read_wal(&vfs, path).unwrap();
        assert_eq!(start, 6);
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[..2], tail[..]);
        assert_eq!(recs[2].seq, 8);
        assert_eq!(recs[2].op, WalOp::Ingest(vec![vec![42]]));
    }

    #[test]
    fn fsync_policy_parse_and_display() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("never").unwrap(), FsyncPolicy::Never);
        assert_eq!(
            FsyncPolicy::parse("batch:16").unwrap(),
            FsyncPolicy::Batch(16)
        );
        assert!(FsyncPolicy::parse("batch:0").is_err());
        assert!(FsyncPolicy::parse("batch:x").is_err());
        assert!(FsyncPolicy::parse("sometimes").is_err());
        for s in ["always", "never", "batch:8"] {
            assert_eq!(FsyncPolicy::parse(s).unwrap().to_string(), s);
        }
    }
}
