//! Pipeline telemetry: per-stage timings, throughput, and backpressure
//! accounting, rendered as a human-readable report by the CLI.

use std::time::Duration;

use crate::util::timer::fmt_duration;

/// One pipeline stage's timing record.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub name: String,
    pub duration: Duration,
    /// Items processed by the stage (transactions, itemsets, rules, ...).
    pub items: usize,
}

impl StageReport {
    pub fn throughput(&self) -> f64 {
        let secs = self.duration.as_secs_f64();
        if secs > 0.0 {
            self.items as f64 / secs
        } else {
            f64::INFINITY
        }
    }
}

/// The full pipeline run report.
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    pub stages: Vec<StageReport>,
    pub producer_blocked: Duration,
    pub consumer_blocked: Duration,
    pub num_transactions: usize,
    pub num_frequent_itemsets: usize,
    pub num_rules: usize,
    pub trie_nodes: usize,
    pub trie_rules_representable: usize,
    pub trie_memory_bytes: usize,
    pub frame_memory_bytes: usize,
    pub counter_backend: &'static str,
    /// Threads the build stages (mine/rulegen/build-trie/build-frame) ran
    /// with: 1 for the sequential path, pool helpers + 1 when a worker
    /// pool was shared in (service STATS echoes this as `build_threads=`).
    pub build_threads: usize,
}

impl PipelineReport {
    pub fn total_duration(&self) -> Duration {
        self.stages.iter().map(|s| s.duration).sum()
    }

    pub fn push_stage(&mut self, name: &str, duration: Duration, items: usize) {
        self.stages.push(StageReport {
            name: name.to_string(),
            duration,
            items,
        });
    }

    /// Markdown-ish rendering for CLI output and EXPERIMENTS.md capture.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("pipeline report\n");
        out.push_str("---------------\n");
        for s in &self.stages {
            out.push_str(&format!(
                "  {:<18} {:>12}  ({} items, {:.0}/s)\n",
                s.name,
                fmt_duration(s.duration),
                s.items,
                s.throughput()
            ));
        }
        out.push_str(&format!(
            "  {:<18} {:>12}\n",
            "total",
            fmt_duration(self.total_duration())
        ));
        out.push_str(&format!(
            "  backpressure: producers blocked {}, consumers blocked {}\n",
            fmt_duration(self.producer_blocked),
            fmt_duration(self.consumer_blocked)
        ));
        out.push_str(&format!(
            "  transactions={} frequent={} rules={} (counter={}, build_threads={})\n",
            self.num_transactions,
            self.num_frequent_itemsets,
            self.num_rules,
            self.counter_backend,
            self.build_threads.max(1)
        ));
        out.push_str(&format!(
            "  trie: {} nodes, {} representable rules, {} KiB (frame: {} KiB)\n",
            self.trie_nodes,
            self.trie_rules_representable,
            self.trie_memory_bytes / 1024,
            self.frame_memory_bytes / 1024
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_all_stages() {
        let mut r = PipelineReport::default();
        r.push_stage("ingest", Duration::from_millis(10), 100);
        r.push_stage("mine", Duration::from_millis(30), 42);
        r.num_transactions = 100;
        r.counter_backend = "bitset";
        let text = r.render();
        assert!(text.contains("ingest"));
        assert!(text.contains("mine"));
        assert!(text.contains("counter=bitset"));
        // Default (unset) build_threads renders as the sequential floor.
        assert!(text.contains("build_threads=1"), "{text}");
        r.build_threads = 4;
        assert!(r.render().contains("build_threads=4"));
        assert_eq!(r.total_duration(), Duration::from_millis(40));
    }

    #[test]
    fn throughput_computation() {
        let s = StageReport {
            name: "x".into(),
            duration: Duration::from_secs(2),
            items: 100,
        };
        assert!((s.throughput() - 50.0).abs() < 1e-9);
    }
}
