//! Pipeline telemetry: per-stage timings, throughput, and backpressure
//! accounting, rendered as a human-readable report by the CLI.

use std::time::Duration;

use crate::obs::registry::MetricsRegistry;
use crate::util::timer::fmt_duration;

/// One pipeline stage's timing record.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub name: String,
    pub duration: Duration,
    /// Items processed by the stage (transactions, itemsets, rules, ...).
    pub items: usize,
}

impl StageReport {
    /// Items/s. Zero-duration stages report 0.0, not infinity: the value
    /// flows into the JSON/JSONL export path, where non-finite floats have
    /// no representation.
    pub fn throughput(&self) -> f64 {
        let secs = self.duration.as_secs_f64();
        if secs > 0.0 {
            self.items as f64 / secs
        } else {
            0.0
        }
    }
}

/// The full pipeline run report.
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    pub stages: Vec<StageReport>,
    pub producer_blocked: Duration,
    pub consumer_blocked: Duration,
    pub num_transactions: usize,
    pub num_frequent_itemsets: usize,
    pub num_rules: usize,
    pub trie_nodes: usize,
    pub trie_rules_representable: usize,
    pub trie_memory_bytes: usize,
    pub frame_memory_bytes: usize,
    pub counter_backend: &'static str,
    /// Threads the build stages (mine/rulegen/build-trie/build-frame) ran
    /// with: 1 for the sequential path, pool helpers + 1 when a worker
    /// pool was shared in (service STATS echoes this as `build_threads=`).
    pub build_threads: usize,
}

impl PipelineReport {
    pub fn total_duration(&self) -> Duration {
        self.stages.iter().map(|s| s.duration).sum()
    }

    pub fn push_stage(&mut self, name: &str, duration: Duration, items: usize) {
        self.stages.push(StageReport {
            name: name.to_string(),
            duration,
            items,
        });
    }

    /// Mirror the report into a live metrics registry: one
    /// `tor_pipeline_stage_seconds{stage="..."}` histogram observation and a
    /// `tor_pipeline_stage_items` counter per stage, plus gauges for the
    /// run-level totals. Idempotent per run — call once after the pipeline
    /// completes.
    pub fn record_into(&self, registry: &MetricsRegistry) {
        for s in &self.stages {
            registry
                .histogram_seconds(&format!("tor_pipeline_stage_seconds{{stage=\"{}\"}}", s.name))
                .observe_duration(s.duration);
            registry
                .counter(&format!("tor_pipeline_stage_items_total{{stage=\"{}\"}}", s.name))
                .add(s.items as u64);
        }
        registry
            .counter("tor_pipeline_producer_blocked_ns_total")
            .add(self.producer_blocked.as_nanos().min(u64::MAX as u128) as u64);
        registry
            .counter("tor_pipeline_consumer_blocked_ns_total")
            .add(self.consumer_blocked.as_nanos().min(u64::MAX as u128) as u64);
        registry.gauge("tor_pipeline_transactions").set(self.num_transactions as i64);
        registry.gauge("tor_pipeline_frequent_itemsets").set(self.num_frequent_itemsets as i64);
        registry.gauge("tor_pipeline_rules").set(self.num_rules as i64);
        registry.gauge("tor_trie_nodes").set(self.trie_nodes as i64);
        registry.gauge("tor_trie_rules_representable").set(self.trie_rules_representable as i64);
        registry.gauge("tor_trie_memory_bytes").set(self.trie_memory_bytes as i64);
        registry.gauge("tor_frame_memory_bytes").set(self.frame_memory_bytes as i64);
        registry.gauge("tor_pipeline_build_threads").set(self.build_threads.max(1) as i64);
    }

    /// Markdown-ish rendering for CLI output and EXPERIMENTS.md capture.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("pipeline report\n");
        out.push_str("---------------\n");
        for s in &self.stages {
            out.push_str(&format!(
                "  {:<18} {:>12}  ({} items, {:.0}/s)\n",
                s.name,
                fmt_duration(s.duration),
                s.items,
                s.throughput()
            ));
        }
        out.push_str(&format!(
            "  {:<18} {:>12}\n",
            "total",
            fmt_duration(self.total_duration())
        ));
        out.push_str(&format!(
            "  backpressure: producers blocked {}, consumers blocked {}\n",
            fmt_duration(self.producer_blocked),
            fmt_duration(self.consumer_blocked)
        ));
        out.push_str(&format!(
            "  transactions={} frequent={} rules={} (counter={}, build_threads={})\n",
            self.num_transactions,
            self.num_frequent_itemsets,
            self.num_rules,
            self.counter_backend,
            self.build_threads.max(1)
        ));
        out.push_str(&format!(
            "  trie: {} nodes, {} representable rules, {} KiB (frame: {} KiB)\n",
            self.trie_nodes,
            self.trie_rules_representable,
            self.trie_memory_bytes / 1024,
            self.frame_memory_bytes / 1024
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_all_stages() {
        let mut r = PipelineReport::default();
        r.push_stage("ingest", Duration::from_millis(10), 100);
        r.push_stage("mine", Duration::from_millis(30), 42);
        r.num_transactions = 100;
        r.counter_backend = "bitset";
        let text = r.render();
        assert!(text.contains("ingest"));
        assert!(text.contains("mine"));
        assert!(text.contains("counter=bitset"));
        // Default (unset) build_threads renders as the sequential floor.
        assert!(text.contains("build_threads=1"), "{text}");
        r.build_threads = 4;
        assert!(r.render().contains("build_threads=4"));
        assert_eq!(r.total_duration(), Duration::from_millis(40));
    }

    #[test]
    fn throughput_computation() {
        let s = StageReport {
            name: "x".into(),
            duration: Duration::from_secs(2),
            items: 100,
        };
        assert!((s.throughput() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn zero_duration_stage_reports_zero_throughput() {
        let s = StageReport {
            name: "x".into(),
            duration: Duration::ZERO,
            items: 100,
        };
        assert_eq!(s.throughput(), 0.0);
        assert!(s.throughput().is_finite());
    }

    #[test]
    fn record_into_registers_stage_and_total_metrics() {
        let mut r = PipelineReport::default();
        r.push_stage("ingest+shard", Duration::from_millis(10), 100);
        r.push_stage("mine", Duration::from_millis(30), 42);
        r.num_transactions = 100;
        r.trie_nodes = 57;
        r.producer_blocked = Duration::from_millis(2);
        let reg = MetricsRegistry::new();
        r.record_into(&reg);
        let text = reg.render_prometheus();
        assert!(text.contains("tor_pipeline_stage_seconds{stage=\"ingest+shard\",quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("tor_pipeline_stage_items_total{stage=\"mine\"} 42"));
        assert!(text.contains("tor_pipeline_transactions 100"));
        assert!(text.contains("tor_trie_nodes 57"));
        assert!(text.contains("tor_pipeline_build_threads 1"));
        assert_eq!(reg.counter("tor_pipeline_producer_blocked_ns_total").get(), 2_000_000);
    }
}
