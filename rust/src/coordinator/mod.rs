//! L3 coordinator: the streaming ARM pipeline (source → sharded ingest with
//! backpressure → mine → rulegen → build), its configuration and telemetry,
//! and the query service over the built Trie of Rules.

pub mod backpressure;
pub mod config;
pub mod pipeline;
pub mod service;
pub mod sharding;
pub mod telemetry;

pub use backpressure::BoundedQueue;
pub use config::{CounterKind, PipelineConfig};
pub use pipeline::{run, PipelineOutput, Source};
pub use service::{serve_tcp, QueryEngine};
pub use sharding::{PartialCounts, ShardRouter};
pub use telemetry::{PipelineReport, StageReport};
