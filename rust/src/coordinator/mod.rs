//! L3 coordinator: the streaming ARM pipeline (source → sharded ingest with
//! backpressure → mine → rulegen → build), its configuration and telemetry,
//! and the query service over the built Trie of Rules — served by a
//! nonblocking high-fanout TCP front end ([`frontend`]) with admission
//! control ([`backpressure::AdmissionControl`]) and a generation-keyed
//! result cache ([`crate::query::cache`]). The durability plane
//! ([`durability`]) makes the incremental serving path crash-safe: a
//! checksummed write-ahead log ([`wal`]) plus atomic checkpoints.

pub mod backpressure;
pub mod config;
pub mod durability;
pub mod frontend;
pub mod netpoll;
pub mod pipeline;
pub mod scatter;
pub mod service;
pub mod sharding;
pub mod telemetry;
pub mod wal;

pub use backpressure::{AdmissionControl, AdmissionPermit, BoundedQueue};
pub use config::{CounterKind, PipelineConfig};
pub use durability::{DurabilityPlane, RecoveryReport};
pub use frontend::{serve_nonblocking, ServeOptions};
pub use pipeline::{run, PipelineOutput, Source};
pub use scatter::ScatterEngine;
pub use service::{serve_tcp, serve_tcp_blocking, QueryEngine};
pub use sharding::{PartialCounts, ShardRouter};
pub use telemetry::{PipelineReport, StageReport};
pub use wal::FsyncPolicy;
