//! The streaming ARM pipeline — the L3 orchestration of the paper's Fig. 2:
//! transactions → frequent-itemset mining → ruleset → Trie of Rules (and
//! the dataframe baseline for comparison).
//!
//! Topology (std threads + [`BoundedQueue`] backpressure):
//!
//! ```text
//!  source thread ──chunks──▶ bounded queue ──▶ N ingest workers
//!       (generator/file)                        (shard-local counts + rows)
//!                                    │ barrier: merge counts, assemble DB
//!                                    ▼
//!             ItemOrder → miner → rulegen → trie + frame
//!             (FP-growth shards, rulegen chunks, and the trie/frame
//!              overlap all run on the shared WorkerPool when one is
//!              handed in — DESIGN.md §12; outputs are byte-identical
//!              to the sequential path at any thread count)
//! ```
//!
//! Ingestion is genuinely streaming (the source never materializes the
//! dataset); mining is batch, as in the paper. Every stage's wall time and
//! the queues' blocked time land in the [`PipelineReport`].

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::baseline::dataframe::RuleFrame;
use crate::coordinator::backpressure::BoundedQueue;
use crate::coordinator::config::{CounterKind, PipelineConfig};
use crate::coordinator::sharding::{PartialCounts, ShardRouter};
use crate::coordinator::telemetry::PipelineReport;
use crate::obs::export::TelemetryExporter;
use crate::obs::registry::MetricsRegistry;
use crate::data::transaction::{TransactionDb, TransactionDbBuilder};
use crate::data::vocab::{ItemId, Vocab};
use crate::mining::apriori::{apriori_with, BitsetCounter, HorizontalCounter};
use crate::mining::counts::{min_count, ItemOrder};
use crate::mining::fpgrowth::{fpgrowth, fpgrowth_parallel};
use crate::mining::itemset::FrequentItemsets;
use crate::mining::{mine, MinerKind};
use crate::query::parallel::WorkerPool;
use crate::rules::rulegen::{generate_rules, generate_rules_parallel, RuleGenConfig};
use crate::rules::ruleset::RuleSet;
use crate::runtime::support_exec::XlaSupportCounter;
use crate::runtime::Runtime;
use crate::trie::trie::TrieOfRules;

/// Where transactions come from.
pub enum Source {
    /// Synthetic stream from a generator config.
    Generated(crate::data::generator::GeneratorConfig),
    /// Basket CSV file.
    Basket(std::path::PathBuf),
    /// Pre-materialized database (tests, benches).
    Db(TransactionDb),
}

/// Everything a pipeline run produces.
#[derive(Debug)]
pub struct PipelineOutput {
    pub db: TransactionDb,
    pub order: ItemOrder,
    pub frequent: FrequentItemsets,
    /// The complete (subset-closed) frequent collection the trie and the
    /// ruleset were built from — identical to `frequent` except under the
    /// FP-max miner, whose own output is maximal-only. The incremental
    /// serving layer seeds its candidate table from this.
    pub closed: FrequentItemsets,
    pub ruleset: RuleSet,
    pub trie: TrieOfRules,
    pub frame: RuleFrame,
    pub report: PipelineReport,
}

impl PipelineOutput {
    /// Convert a pipeline run into the incremental serving store (the
    /// `INGEST`/`COMPACT` stage of the service): the trie keeps serving as
    /// the frozen base while the retained database and candidate counts
    /// let ingested batches merge exactly. Returns the store, the
    /// vocabulary (for the engine), and the build report.
    pub fn into_incremental(
        self,
        config: &PipelineConfig,
    ) -> Result<(crate::trie::delta::IncrementalTrie, Vocab, PipelineReport)> {
        let vocab = self.db.vocab().clone();
        let store = crate::trie::delta::IncrementalTrie::new(
            self.trie,
            self.db,
            &self.closed,
            config.minsup,
        )?;
        Ok((store, vocab, self.report))
    }
}

/// Run the full pipeline. `runtime` is required only for
/// [`CounterKind::Xla`].
pub fn run(
    source: Source,
    config: &PipelineConfig,
    runtime: Option<&Runtime>,
) -> Result<PipelineOutput> {
    run_with_pool(source, config, runtime, None)
}

/// [`run`] with an optional worker pool. The serve/query launchers hand in
/// the query executor's pool so one pool serves the whole process: the
/// mining shard loop, the rulegen chunk loop, and the overlapped
/// build-trie/build-frame stages all run on it, then the same threads
/// execute queries (DESIGN.md §11/§12, pool lifecycle). Every parallel
/// stage is parity-exact with its sequential twin, so `run` and
/// `run_with_pool` produce byte-identical outputs at any thread count.
pub fn run_with_pool(
    source: Source,
    config: &PipelineConfig,
    runtime: Option<&Runtime>,
    pool: Option<&WorkerPool>,
) -> Result<PipelineOutput> {
    run_observed(source, config, runtime, pool, None, None)
}

/// [`run_with_pool`] with the observability plane attached. A registry
/// receives the ingest queue's live depth/blocked gauges during the run and
/// the full [`PipelineReport`] afterwards
/// ([`PipelineReport::record_into`]); an exporter receives one
/// `pipeline_stage` JSONL record per stage. Both are pure mirrors — the
/// built outputs are byte-identical with or without them.
pub fn run_observed(
    source: Source,
    config: &PipelineConfig,
    runtime: Option<&Runtime>,
    pool: Option<&WorkerPool>,
    registry: Option<&MetricsRegistry>,
    exporter: Option<&TelemetryExporter>,
) -> Result<PipelineOutput> {
    config.validate()?;
    let mut report = PipelineReport::default();
    report.counter_backend = config.counter.name();
    // A pool with no helpers adds dispatch overhead and zero concurrency;
    // treat it as absent for the build stages.
    let build_pool = pool.filter(|p| p.helpers() > 0);
    report.build_threads = build_pool.map(|p| p.helpers() + 1).unwrap_or(1);

    // ---------------------------------------------------------------
    // Stage 1+2: streaming ingestion through the bounded queue into
    // shard workers (counts + shard-local rows), then merge.
    // ---------------------------------------------------------------
    let t0 = Instant::now();
    let (db, merged, (producer_blocked, consumer_blocked)) = ingest(source, config, registry)?;
    report.push_stage("ingest+shard", t0.elapsed(), db.num_transactions());
    report.num_transactions = db.num_transactions();
    report.producer_blocked = producer_blocked;
    report.consumer_blocked = consumer_blocked;
    anyhow::ensure!(db.num_transactions() > 0, "no transactions ingested");
    debug_assert_eq!(merged.freqs, db.item_frequencies());

    // ---------------------------------------------------------------
    // Stage 3: mining — header-sharded across the pool for FP-growth
    // (parity-exact with the sequential miner), leader-only otherwise.
    // ---------------------------------------------------------------
    let t0 = Instant::now();
    let order = ItemOrder::from_frequencies(
        merged.freqs.clone(),
        min_count(config.minsup, db.num_transactions()),
    );
    let frequent = match (config.miner, config.counter) {
        (MinerKind::Apriori, CounterKind::Bitset) => {
            let mut c = BitsetCounter::new(&db);
            apriori_with(&db, config.minsup, &mut c)
        }
        (MinerKind::Apriori, CounterKind::Horizontal) => {
            let mut c = HorizontalCounter::new(&db);
            apriori_with(&db, config.minsup, &mut c)
        }
        (MinerKind::Apriori, CounterKind::Xla) => {
            let rt = runtime.context("counter=xla needs a loaded Runtime")?;
            let mut c = XlaSupportCounter::new(rt, &db)?;
            apriori_with(&db, config.minsup, &mut c)
        }
        (MinerKind::FpGrowth, _) => match build_pool {
            Some(p) => fpgrowth_parallel(&db, config.minsup, p),
            None => fpgrowth(&db, config.minsup),
        },
        (kind, _) => mine(&db, config.minsup, kind),
    };
    report.push_stage("mine", t0.elapsed(), frequent.len());
    report.num_frequent_itemsets = frequent.len();

    // ---------------------------------------------------------------
    // Stage 4: rule generation (the dataframe's input).
    // FP-max output is not subset-closed, so rulegen runs on a full
    // frequent set mined alongside when needed.
    // ---------------------------------------------------------------
    let t0 = Instant::now();
    let closed = if config.miner == MinerKind::FpMax {
        match build_pool {
            Some(p) => fpgrowth_parallel(&db, config.minsup, p),
            None => fpgrowth(&db, config.minsup),
        }
    } else {
        frequent.clone()
    };
    let rule_cfg = RuleGenConfig {
        min_confidence: config.min_confidence,
        max_consequent: usize::MAX,
    };
    let ruleset = match build_pool {
        Some(p) => generate_rules_parallel(&closed, rule_cfg, p),
        None => generate_rules(&closed, rule_cfg),
    };
    report.push_stage("rulegen", t0.elapsed(), ruleset.len());
    report.num_rules = ruleset.len();

    // ---------------------------------------------------------------
    // Stage 5: build both representations. The trie goes straight to its
    // frozen columnar (CSR) serving layout via the sort-based one-pass
    // constructor — no mutable TrieNode arena in the pipeline anymore
    // (TrieBuilder remains as the parity oracle and the
    // maximal-sequence path). Trie and frame construction are
    // independent; with a worker pool they overlap on two tasks.
    // Durations are measured inside each task, so the report still
    // attributes per-stage time truthfully when the stages run
    // concurrently.
    let (trie, trie_t, frame, frame_t) = match build_pool {
        Some(pool) => {
            type TrieSlot = Option<(Result<TrieOfRules>, std::time::Duration)>;
            let trie_slot: Mutex<TrieSlot> = Mutex::new(None);
            let frame_slot: Mutex<Option<(RuleFrame, std::time::Duration)>> = Mutex::new(None);
            pool.run(2, |task| {
                if task == 0 {
                    let t0 = Instant::now();
                    let trie = TrieOfRules::from_sorted_paths(&closed, &order);
                    *trie_slot.lock().unwrap() = Some((trie, t0.elapsed()));
                } else {
                    let t0 = Instant::now();
                    let frame = RuleFrame::from_ruleset(&ruleset);
                    *frame_slot.lock().unwrap() = Some((frame, t0.elapsed()));
                }
            });
            let (trie, trie_t) = trie_slot.into_inner().unwrap().expect("trie task ran");
            let (frame, frame_t) = frame_slot.into_inner().unwrap().expect("frame task ran");
            (trie?, trie_t, frame, frame_t)
        }
        None => {
            let t0 = Instant::now();
            let trie = TrieOfRules::from_sorted_paths(&closed, &order)?;
            let trie_t = t0.elapsed();
            let t0 = Instant::now();
            let frame = RuleFrame::from_ruleset(&ruleset);
            (trie, trie_t, frame, t0.elapsed())
        }
    };
    report.push_stage("build-trie", trie_t, trie.num_nodes());
    report.push_stage("build-frame", frame_t, frame.len());
    report.trie_nodes = trie.num_nodes();
    report.trie_rules_representable = trie.num_representable_rules();
    report.trie_memory_bytes = trie.memory_bytes();
    report.frame_memory_bytes = frame.memory_bytes();

    if let Some(registry) = registry {
        report.record_into(registry);
    }
    if let Some(exporter) = exporter {
        for s in &report.stages {
            exporter.emit_pipeline_stage(&s.name, s.duration, s.items, s.throughput());
        }
        exporter.flush();
    }

    Ok(PipelineOutput {
        db,
        order,
        frequent,
        closed,
        ruleset,
        trie,
        frame,
        report,
    })
}

/// Stage 1+2: stream chunks through the bounded queue into shard workers.
/// Returns the DB, merged counts, and the queue's (producer, consumer)
/// blocked time for the report's backpressure line.
fn ingest(
    source: Source,
    config: &PipelineConfig,
    registry: Option<&MetricsRegistry>,
) -> Result<(TransactionDb, PartialCounts, (Duration, Duration))> {
    // Fast path: an already-materialized DB skips the thread topology but
    // still produces merged counts (tests rely on identical outputs).
    if let Source::Db(db) = source {
        let mut counts = PartialCounts::new(db.num_items());
        for tx in db.iter() {
            counts.observe(tx);
        }
        return Ok((db, counts, (Duration::ZERO, Duration::ZERO)));
    }

    let (vocab, mut next_chunk): (Vocab, Box<dyn FnMut(usize) -> Vec<Vec<ItemId>> + Send>) =
        match source {
            Source::Generated(cfg) => {
                let mut stream = crate::data::generator::TransactionStream::new(cfg);
                let vocab = stream.vocab();
                (vocab, Box::new(move |max| stream.next_chunk(max)))
            }
            Source::Basket(path) => {
                // Files are parsed up-front (interning needs a single
                // writer) and then replayed through the same chunk stream.
                let db = crate::data::loader::load_basket(&path)?;
                let vocab = db.vocab().clone();
                let mut txs: std::collections::VecDeque<Vec<ItemId>> =
                    db.iter().map(|t| t.to_vec()).collect();
                (
                    vocab,
                    Box::new(move |max| {
                        let n = max.min(txs.len());
                        txs.drain(..n).collect()
                    }),
                )
            }
            Source::Db(_) => unreachable!("handled above"),
        };

    let queue: BoundedQueue<(u64, Vec<Vec<ItemId>>)> = BoundedQueue::new(config.queue_capacity);
    if let Some(registry) = registry {
        queue.bind_metrics(registry, "tor_pipeline_queue");
    }
    let router = ShardRouter::new(config.workers, config.shard_slots);
    let num_items = vocab.len();

    // Worker state: shard-local rows + partial counts.
    struct ShardState {
        rows: Vec<Vec<ItemId>>,
        counts: PartialCounts,
    }
    let shards: Arc<Vec<Mutex<ShardState>>> = Arc::new(
        (0..config.workers)
            .map(|_| {
                Mutex::new(ShardState {
                    rows: Vec::new(),
                    counts: PartialCounts::new(num_items),
                })
            })
            .collect(),
    );

    std::thread::scope(|scope| -> Result<()> {
        // Source thread.
        let q_src = queue.clone();
        let chunk_size = config.chunk_size;
        let src = scope.spawn(move || {
            let mut tid0 = 0u64;
            loop {
                let chunk = next_chunk(chunk_size);
                if chunk.is_empty() {
                    break;
                }
                let len = chunk.len() as u64;
                if q_src.push((tid0, chunk)).is_err() {
                    break;
                }
                tid0 += len;
            }
            q_src.close();
        });

        // Ingest workers.
        let mut handles = Vec::new();
        for _ in 0..config.workers {
            let q = queue.clone();
            let shards = Arc::clone(&shards);
            let router = router.clone();
            handles.push(scope.spawn(move || {
                while let Some((tid0, chunk)) = q.pop() {
                    for (off, tx) in chunk.into_iter().enumerate() {
                        let shard = router.route(tid0 + off as u64);
                        let mut st = shards[shard].lock().unwrap();
                        st.counts.observe(&tx);
                        st.rows.push(tx);
                    }
                }
            }));
        }
        src.join().ok();
        for h in handles {
            h.join().ok();
        }
        Ok(())
    })?;

    // Barrier: merge shards into one DB + merged counts.
    let mut builder: TransactionDbBuilder = TransactionDb::builder(vocab);
    let mut merged = PartialCounts::new(num_items);
    let shards = Arc::try_unwrap(shards).ok().expect("shard refs leaked");
    for shard in shards {
        let st = shard.into_inner().unwrap();
        merged.merge(&st.counts);
        for row in st.rows {
            builder.push_ids(row);
        }
    }
    let db = builder.build();
    // `observe` counted raw rows (pre-dedup); recount exactly when any
    // transaction had duplicate items.
    let exact = db.item_frequencies();
    let merged = if exact != merged.freqs {
        PartialCounts {
            freqs: exact,
            transactions: db.num_transactions(),
        }
    } else {
        merged
    };
    Ok((db, merged, queue.blocked_times()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::GeneratorConfig;
    use crate::data::transaction::paper_example_db;
    use crate::trie::trie::FindOutcome;

    #[test]
    fn pipeline_on_generated_source() {
        let cfg = PipelineConfig {
            minsup: 0.05,
            workers: 3,
            chunk_size: 17,
            queue_capacity: 4,
            ..Default::default()
        };
        let out = run(
            Source::Generated(GeneratorConfig::tiny(42)),
            &cfg,
            None,
        )
        .unwrap();
        assert_eq!(out.db.num_transactions(), 200);
        assert!(!out.frequent.is_empty());
        assert!(!out.ruleset.is_empty());
        assert!(out.trie.num_nodes() > 0);
        assert_eq!(out.frame.len(), out.ruleset.len());
        assert!(out.report.total_duration().as_nanos() > 0);
        assert_eq!(out.report.num_transactions, 200);
    }

    #[test]
    fn pipeline_output_matches_direct_mining() {
        // The sharded/streamed path must produce the same frequent itemsets
        // as mining the materialized database directly (order-insensitive).
        let gen = GeneratorConfig::tiny(7);
        let direct_db = gen.generate();
        let direct = crate::mining::fpgrowth::fpgrowth(&direct_db, 0.05);
        let cfg = PipelineConfig {
            minsup: 0.05,
            miner: MinerKind::FpGrowth,
            workers: 4,
            chunk_size: 13,
            ..Default::default()
        };
        let out = run(Source::Generated(gen), &cfg, None).unwrap();
        // Transactions arrive shard-reordered; itemset supports must agree.
        let mut got = out.frequent.clone();
        let mut want = direct.clone();
        got.canonicalize();
        want.canonicalize();
        assert_eq!(got.sets, want.sets);
    }

    #[test]
    fn pipeline_on_db_source_finds_paper_rule() {
        let db = paper_example_db();
        let cfg = PipelineConfig {
            minsup: 0.3,
            workers: 2,
            ..Default::default()
        };
        let vocab = db.vocab().clone();
        let out = run(Source::Db(db), &cfg, None).unwrap();
        let name = |s: &str| vocab.get(s).unwrap();
        let rule = crate::rules::rule::Rule::from_ids(
            vec![name("f"), name("c")],
            vec![name("a")],
        );
        match out.trie.find_rule(&rule) {
            FindOutcome::Found(m) => assert!((m.confidence - 1.0).abs() < 1e-12),
            other => panic!("expected Found, got {other:?}"),
        }
        // Frame and trie were built from the same closed frequent set.
        let (_, fm) = out.frame.find(&rule).expect("rule in frame");
        assert!((fm.confidence - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pipeline_via_basket_file() {
        let db = GeneratorConfig::tiny(9).generate();
        let dir = std::env::temp_dir().join(format!("tor_pipe_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tx.csv");
        crate::data::loader::save_basket(&db, &path).unwrap();
        let cfg = PipelineConfig {
            minsup: 0.05,
            workers: 2,
            chunk_size: 11,
            ..Default::default()
        };
        let out = run(Source::Basket(path), &cfg, None).unwrap();
        assert_eq!(out.db.num_transactions(), db.num_transactions());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fpmax_miner_still_builds_full_ruleset() {
        let cfg = PipelineConfig {
            minsup: 0.05,
            miner: MinerKind::FpMax,
            ..Default::default()
        };
        let out = run(Source::Generated(GeneratorConfig::tiny(3)), &cfg, None).unwrap();
        // FP-max frequent list is maximal-only, but rulegen/trie use the
        // closed set mined alongside.
        assert!(out.ruleset.len() >= out.frequent.len());
        assert!(out.trie.num_nodes() >= out.frequent.len());
    }

    #[test]
    fn pooled_build_matches_sequential_build() {
        // The parallel build pipeline (sharded mining, chunked rulegen,
        // overlapped trie/frame stages) must produce byte-identical
        // outputs to the sequential build at every thread count.
        let gen = GeneratorConfig::tiny(21);
        let cfg = PipelineConfig {
            minsup: 0.05,
            ..Default::default()
        };
        let seq = run(Source::Generated(gen.clone()), &cfg, None).unwrap();
        assert_eq!(seq.report.build_threads, 1);
        for helpers in [1usize, 3, 7] {
            let pool = WorkerPool::new(helpers);
            let par =
                run_with_pool(Source::Generated(gen.clone()), &cfg, None, Some(&pool)).unwrap();
            assert_eq!(seq.frequent.sets, par.frequent.sets, "helpers={helpers}");
            assert_eq!(
                seq.ruleset.rules(),
                par.ruleset.rules(),
                "helpers={helpers}"
            );
            assert_eq!(seq.trie.items_column(), par.trie.items_column());
            assert_eq!(seq.trie.counts_column(), par.trie.counts_column());
            assert_eq!(seq.trie.parents_column(), par.trie.parents_column());
            assert_eq!(seq.trie.depths_column(), par.trie.depths_column());
            assert_eq!(seq.trie.subtree_end_column(), par.trie.subtree_end_column());
            assert_eq!(seq.trie.child_csr(), par.trie.child_csr());
            assert_eq!(seq.trie.header_csr(), par.trie.header_csr());
            assert_eq!(seq.frame.len(), par.frame.len());
            // Both build stages were still timed and reported, and the
            // report carries the effective build parallelism.
            let stages: Vec<&str> = par.report.stages.iter().map(|s| s.name.as_str()).collect();
            assert!(stages.contains(&"build-trie") && stages.contains(&"build-frame"));
            assert_eq!(par.report.build_threads, helpers + 1);
        }
    }

    #[test]
    fn observed_run_mirrors_stages_without_changing_outputs() {
        let gen = GeneratorConfig::tiny(13);
        let cfg = PipelineConfig {
            minsup: 0.05,
            workers: 2,
            chunk_size: 16,
            queue_capacity: 2,
            ..Default::default()
        };
        let plain = run(Source::Generated(gen.clone()), &cfg, None).unwrap();
        let registry = MetricsRegistry::new();
        let path = std::env::temp_dir().join(format!(
            "tor_pipe_obs_{}.jsonl",
            std::process::id()
        ));
        let exporter = TelemetryExporter::create(path.to_str().unwrap()).unwrap();
        let observed = run_observed(
            Source::Generated(gen),
            &cfg,
            None,
            None,
            Some(&registry),
            Some(&exporter),
        )
        .unwrap();
        // Pure mirror: identical build outputs.
        assert_eq!(plain.frequent.sets, observed.frequent.sets);
        assert_eq!(plain.trie.items_column(), observed.trie.items_column());
        // Registry carries every stage plus the structural gauges.
        let text = registry.render_prometheus();
        for stage in ["ingest+shard", "mine", "rulegen", "build-trie", "build-frame"] {
            assert!(
                text.contains(&format!("tor_pipeline_stage_seconds{{stage=\"{stage}\"")),
                "missing {stage} in:\n{text}"
            );
        }
        assert!(text.contains("tor_trie_nodes"), "{text}");
        assert!(text.contains("tor_pipeline_queue_depth"), "{text}");
        // Exporter wrote one pipeline_stage record per stage.
        exporter.sync();
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), observed.report.stages.len());
        for line in lines {
            let v = crate::util::json::Json::parse(line).unwrap();
            assert_eq!(v.get("type").unwrap().as_str(), Some("pipeline_stage"));
            assert!(v.get("duration_s").unwrap().as_f64().unwrap() >= 0.0);
        }
        drop(exporter);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_runtime_for_xla_errors() {
        let mut cfg = PipelineConfig::default();
        cfg.counter = CounterKind::Xla;
        cfg.minsup = 0.05;
        let err = run(Source::Generated(GeneratorConfig::tiny(1)), &cfg, None).unwrap_err();
        assert!(err.to_string().contains("Runtime"));
    }
}
