//! Query service: the request loop over a built Trie of Rules.
//!
//! Two frontends share one engine:
//! * an in-process [`QueryEngine`] (used by the CLI and benches), and
//! * a line-protocol TCP server (`tor serve`) — one command per line,
//!   one response per line, so the structure is queryable from anywhere
//!   without Python ever entering the request path.
//!
//! Protocol (full grammar + wire format in DESIGN.md §8):
//! ```text
//! RULES [WHERE ...] [SORT BY ...] [LIMIT k]  -> RQL result rows
//! EXPLAIN RULES ...        -> the planned access path, no execution
//! FIND a,b => c            -> FOUND sup=.. conf=.. lift=..  | ABSENT | NOTREP
//! TOP <metric> <k>         -> sugar for `RULES SORT BY <metric> DESC LIMIT k`
//! CONSEQ c                 -> sugar for `RULES WHERE conseq = c`
//! SUPPORT a,b              -> SUPPORT <count>               | ABSENT
//! STATS                    -> node/rule/memory/thread counters
//! QUIT
//! ```
//!
//! `RULES`/`EXPLAIN` route through the [`crate::query`] engine (parser →
//! trie-aware planner → streaming executor). `TOP` and `CONSEQ` are kept
//! as legacy sugar: they desugar to the RQL AST and run through the same
//! engine, only their response formatting is bespoke. `FIND` and
//! `SUPPORT` stay native point lookups — they answer in O(path) via
//! [`TrieOfRules::find_rule`] and need the three-way
//! FOUND/ABSENT/NOTREP distinction that a row-set query cannot express.
//!
//! **Incremental serving** (DESIGN.md §13): an engine built with
//! [`QueryEngine::with_incremental`] additionally accepts
//!
//! ```text
//! INGEST a,b,c;d,e     -> absorb transactions (`;`-separated) online
//! COMPACT              -> merge the delta into a fresh frozen snapshot
//! SNAPSHOT /path       -> persist the snapshot (+ pending-delta sidecar)
//! ```
//!
//! Every request pins the current [`MergedView`] (an `Arc` pair of frozen
//! base + delta overlay); `INGEST`/`COMPACT` build the next view and swap
//! it in atomically, so in-flight queries finish on the epoch they
//! started on and `RULES` output is parity-exact with a from-scratch
//! batch rebuild at every point in the update stream
//! (`rust/tests/incremental_parity.rs`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::durability::DurabilityPlane;
use super::frontend::{self, ServeOptions, MAX_REQUEST_BYTES};
use crate::data::vocab::Vocab;
use crate::obs::export::TelemetryExporter;
use crate::obs::registry::{Counter, Gauge, Histogram, MetricsRegistry};
use crate::query::cache::ResultCache;
use crate::query::ast::{Pred, Query as RqlQuery, SortSpec};
use crate::query::exec::{QueryOutput, Row};
use crate::query::parallel::{default_query_threads, ParallelExecutor};
use crate::rules::metrics::Metric;
use crate::rules::rule::Rule;
use crate::trie::delta::{IncrementalTrie, MergedView};
use crate::trie::trie::{FindOutcome, TrieOfRules};

/// Protocol verbs, as bucketed for per-verb service metrics. `Other`
/// absorbs unknown commands so malformed input still shows up in latency
/// and error accounting instead of vanishing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Verb {
    Rules,
    Explain,
    Find,
    Top,
    Conseq,
    Support,
    Ingest,
    Compact,
    Snapshot,
    Stats,
    Metrics,
    Other,
    /// Appended after `Other` so every pre-existing `q_<verb>=` STATS
    /// position (including `q_other=` at index 11) is unchanged.
    Scatter,
}

impl Verb {
    /// Every verb, in the fixed order used for metric registration and the
    /// `q_<verb>=` tail of STATS.
    const ALL: [Verb; 13] = [
        Verb::Rules,
        Verb::Explain,
        Verb::Find,
        Verb::Top,
        Verb::Conseq,
        Verb::Support,
        Verb::Ingest,
        Verb::Compact,
        Verb::Snapshot,
        Verb::Stats,
        Verb::Metrics,
        Verb::Other,
        Verb::Scatter,
    ];

    fn name(self) -> &'static str {
        match self {
            Verb::Rules => "rules",
            Verb::Explain => "explain",
            Verb::Find => "find",
            Verb::Top => "top",
            Verb::Conseq => "conseq",
            Verb::Support => "support",
            Verb::Ingest => "ingest",
            Verb::Compact => "compact",
            Verb::Snapshot => "snapshot",
            Verb::Stats => "stats",
            Verb::Metrics => "metrics",
            Verb::Other => "other",
            Verb::Scatter => "scatter",
        }
    }

    /// Classify an already-uppercased command word.
    fn of(cmd: &str) -> Verb {
        match cmd {
            "RULES" => Verb::Rules,
            "EXPLAIN" => Verb::Explain,
            "FIND" => Verb::Find,
            "TOP" => Verb::Top,
            "CONSEQ" => Verb::Conseq,
            "SUPPORT" => Verb::Support,
            "INGEST" => Verb::Ingest,
            "COMPACT" => Verb::Compact,
            "SNAPSHOT" => Verb::Snapshot,
            "STATS" => Verb::Stats,
            "METRICS" => Verb::Metrics,
            "SCATTER" => Verb::Scatter,
            _ => Verb::Other,
        }
    }
}

/// Whether a request line may be answered from the result cache: its verb
/// must be a pure function of (request text, serving view). `INGEST` /
/// `COMPACT` / `SNAPSHOT` mutate, `STATS` / `METRICS` report live
/// counters, and `ANALYZE` runs carry wall-clock work numbers — all are
/// excluded. The key is the *trimmed request line verbatim*; no further
/// normalization, because RQL item names are case- and
/// whitespace-sensitive, so any rewriting could merge distinct queries.
fn cacheable(verb: Verb, line: &str) -> bool {
    match verb {
        Verb::Rules | Verb::Explain | Verb::Find | Verb::Top | Verb::Conseq | Verb::Support => {
            !line
                .split_whitespace()
                .any(|t| t.eq_ignore_ascii_case("ANALYZE"))
        }
        _ => false,
    }
}

/// The engine's observability plane: a metrics registry plus pre-bound
/// handles for everything the request path touches. Always present (so
/// `METRICS` works on any engine); `enabled = false` strips the per-query
/// clock reads and counter updates for overhead measurement
/// (`benches/obs_overhead.rs`) while leaving response bytes identical.
struct ServiceObs {
    registry: Arc<MetricsRegistry>,
    enabled: bool,
    start: Instant,
    /// Per-verb request counters (`tor_queries_total{verb="..."}`),
    /// indexed by `Verb as usize`.
    verb_count: [Counter; 13],
    /// Per-verb latency histograms (`tor_query_seconds{verb="..."}`).
    verb_latency: [Histogram; 13],
    active_conns: Gauge,
    uptime_seconds: Gauge,
    ingest_batch_tx: Histogram,
    compact_pause_seconds: Histogram,
    epoch: Gauge,
    pending_tx: Gauge,
    delta_nodes: Gauge,
    /// Requests refused with `BUSY` by the front end's admission control.
    shed_requests: Counter,
    /// Connections evicted by the front end's idle timeout.
    idle_evicted_conns: Counter,
    /// Result-cache accounting (`tor_result_cache_*`); all zero unless the
    /// engine was built `with_result_cache`.
    result_cache_hits: Counter,
    result_cache_misses: Counter,
    result_cache_evictions: Counter,
    result_cache_invalidations: Counter,
    result_cache_bytes: Gauge,
    result_cache_entries: Gauge,
    exporter: Option<Arc<TelemetryExporter>>,
}

impl ServiceObs {
    fn new(registry: Arc<MetricsRegistry>, exporter: Option<Arc<TelemetryExporter>>) -> Self {
        let verb_count = Verb::ALL
            .map(|v| registry.counter(&format!("tor_queries_total{{verb=\"{}\"}}", v.name())));
        let verb_latency = Verb::ALL.map(|v| {
            registry.histogram_seconds(&format!("tor_query_seconds{{verb=\"{}\"}}", v.name()))
        });
        ServiceObs {
            enabled: true,
            start: Instant::now(),
            verb_count,
            verb_latency,
            active_conns: registry.gauge("tor_active_connections"),
            uptime_seconds: registry.gauge("tor_uptime_seconds"),
            ingest_batch_tx: registry.histogram("tor_ingest_batch_tx"),
            compact_pause_seconds: registry.histogram_seconds("tor_compact_pause_seconds"),
            epoch: registry.gauge("tor_epoch"),
            pending_tx: registry.gauge("tor_pending_tx"),
            delta_nodes: registry.gauge("tor_delta_nodes"),
            shed_requests: registry.counter("tor_shed_requests_total"),
            idle_evicted_conns: registry.counter("tor_idle_evicted_conns_total"),
            result_cache_hits: registry.counter("tor_result_cache_hits_total"),
            result_cache_misses: registry.counter("tor_result_cache_misses_total"),
            result_cache_evictions: registry.counter("tor_result_cache_evictions_total"),
            result_cache_invalidations: registry.counter("tor_result_cache_invalidations_total"),
            result_cache_bytes: registry.gauge("tor_result_cache_bytes"),
            result_cache_entries: registry.gauge("tor_result_cache_entries"),
            exporter,
            registry,
        }
    }

    fn uptime_s(&self) -> u64 {
        let s = self.start.elapsed().as_secs();
        self.uptime_seconds.set(s as i64);
        s
    }
}

/// In-process query engine over a built trie. Owns one
/// [`ParallelExecutor`] — and with it one worker pool — for its whole
/// lifetime: every request (in-process or from any TCP connection) runs
/// through the same pool, so thread spin-up is paid once per process, not
/// per query.
///
/// The serving state is a swappable [`MergedView`]: requests clone the
/// `Arc` under a short lock and run on that pinned snapshot; `INGEST` /
/// `COMPACT` (available when the engine carries an [`IncrementalTrie`])
/// replace it atomically.
/// The swappable serving state. `generation` advances on **every** view
/// install — INGEST and COMPACT alike — which is what the result cache
/// keys on. (`MergedView::epoch` is *not* a safe cache key: it only
/// advances on compaction, while INGEST changes query results without
/// touching it.) View and generation live under one lock so a reader can
/// never observe a new view paired with a stale generation or vice versa.
struct Serving {
    view: Arc<MergedView>,
    generation: u64,
}

pub struct QueryEngine {
    vocab: Vocab,
    queries: AtomicU64,
    exec: ParallelExecutor,
    /// The pinned serving state; swapped whole on ingest/compaction.
    serving: Mutex<Serving>,
    /// Generation-keyed response cache (`--result-cache-mb`; `None` = off).
    cache: Option<ResultCache>,
    /// The mutable incremental store (None for static engines, e.g. a trie
    /// loaded from disk without its database).
    store: Option<Mutex<IncrementalTrie>>,
    /// Pending-transaction count that triggers auto-compaction inside
    /// `INGEST` (0 = compact only on explicit `COMPACT`).
    compact_threshold: usize,
    /// Threads the build pipeline ran with (0 = unknown, e.g. a trie
    /// loaded from disk); surfaced in STATS as `build_threads=`.
    build_threads: usize,
    /// Shard identity under scatter-gather serving (`--shard-of k/K`):
    /// `SCATTER` requests execute only this shard's partition and STATS
    /// grows a ` shard=k/K` tail. `None` = standalone single-node engine.
    shard_of: Option<(usize, usize)>,
    /// Metrics + telemetry plane (always constructed; see [`ServiceObs`]).
    obs: ServiceObs,
    /// Crash-safety plane (`--wal-dir`): WAL + checkpoints + degraded
    /// mode. `None` keeps every response byte-identical to a WAL-less
    /// engine (the gauges below are only registered when attached).
    durability: Option<(Arc<DurabilityPlane>, DurabilityObs)>,
}

/// Pre-bound gauges mirroring the durability plane's counters into the
/// engine's metrics registry. Registered only by
/// [`QueryEngine::with_durability`], so a WAL-less engine's `METRICS`
/// exposition is unchanged.
struct DurabilityObs {
    degraded: Gauge,
    wal_appends: Gauge,
    checkpoints: Gauge,
}

impl DurabilityObs {
    fn refresh(&self, plane: &DurabilityPlane) {
        self.degraded.set(i64::from(plane.is_degraded()));
        self.wal_appends.set(plane.wal_appends() as i64);
        self.checkpoints.set(plane.checkpoints_written() as i64);
    }
}

impl QueryEngine {
    /// Engine with the default degree of parallelism
    /// ([`default_query_threads`]: available cores, capped).
    pub fn new(trie: TrieOfRules, vocab: Vocab) -> Self {
        Self::with_threads(trie, vocab, default_query_threads())
    }

    /// Engine with an explicit degree (`--query-threads`; 1 = sequential).
    pub fn with_threads(trie: TrieOfRules, vocab: Vocab, threads: usize) -> Self {
        Self::with_executor(trie, vocab, ParallelExecutor::new(threads))
    }

    /// Engine around an existing executor (so its pool can be shared with
    /// the pipeline's build stages before serving starts).
    pub fn with_executor(trie: TrieOfRules, vocab: Vocab, exec: ParallelExecutor) -> Self {
        Self {
            vocab,
            queries: AtomicU64::new(0),
            exec,
            serving: Mutex::new(Serving {
                view: Arc::new(MergedView::from_trie(trie)),
                generation: 0,
            }),
            cache: None,
            store: None,
            compact_threshold: 0,
            build_threads: 0,
            shard_of: None,
            obs: ServiceObs::new(Arc::new(MetricsRegistry::new()), None),
            durability: None,
        }
    }

    /// Engine over an incremental store: serves the store's current view
    /// and accepts `INGEST`/`COMPACT`/`SNAPSHOT`.
    pub fn with_incremental(store: IncrementalTrie, vocab: Vocab, exec: ParallelExecutor) -> Self {
        let view = Arc::new(store.view());
        Self {
            vocab,
            queries: AtomicU64::new(0),
            exec,
            serving: Mutex::new(Serving {
                view,
                generation: 0,
            }),
            cache: None,
            store: Some(Mutex::new(store)),
            compact_threshold: 0,
            build_threads: 0,
            shard_of: None,
            obs: ServiceObs::new(Arc::new(MetricsRegistry::new()), None),
            durability: None,
        }
    }

    /// Attach the crash-safety plane (`--wal-dir`): every INGEST batch is
    /// WAL-logged before it is applied or acknowledged, COMPACT
    /// checkpoints + truncates the log, and a WAL/checkpoint write
    /// failure flips the service to read-only degraded mode instead of
    /// panicking. Call *after* [`QueryEngine::with_observability`] so the
    /// `tor_degraded` / `tor_wal_appends` / `tor_checkpoints` gauges land
    /// in the final registry.
    pub fn with_durability(mut self, plane: Arc<DurabilityPlane>) -> Self {
        let obs = DurabilityObs {
            degraded: self.obs.registry.gauge("tor_degraded"),
            wal_appends: self.obs.registry.gauge("tor_wal_appends"),
            checkpoints: self.obs.registry.gauge("tor_checkpoints"),
        };
        obs.refresh(&plane);
        self.durability = Some((plane, obs));
        self
    }

    /// The attached durability plane, if any.
    pub fn durability(&self) -> Option<&Arc<DurabilityPlane>> {
        self.durability.as_ref().map(|(p, _)| p)
    }

    /// Shutdown drain: force the WAL durable (regardless of fsync policy)
    /// and flush + fsync the telemetry exporter, so an orderly stop loses
    /// neither acknowledged mutations nor buffered telemetry records.
    pub fn shutdown_flush(&self) {
        if let Some((plane, obs)) = &self.durability {
            if plane.shutdown_flush().is_err() {
                obs.refresh(plane);
            }
        }
        if let Some(exporter) = &self.obs.exporter {
            exporter.flush();
            exporter.sync();
        }
    }

    /// Record the build pipeline's thread count (from
    /// [`crate::coordinator::telemetry::PipelineReport::build_threads`])
    /// so STATS can report it alongside the query degree.
    pub fn with_build_threads(mut self, build_threads: usize) -> Self {
        self.build_threads = build_threads;
        self
    }

    /// Declare this engine shard `k` of `n` in a scatter-gather fleet
    /// (`--shard-of k/K`). Only affects `SCATTER` (which executes exactly
    /// this partition of the rule space) and the STATS ` shard=` tail;
    /// every other verb still serves the full rule space, so a shard can
    /// answer forwarded point lookups and broadcast mutations.
    pub fn with_shard_identity(mut self, k: usize, n: usize) -> Self {
        assert!(n > 0 && k < n, "shard {k}/{n} out of range");
        self.shard_of = Some((k, n));
        self
    }

    /// Auto-compact once this many transactions are pending (config key
    /// `compact_threshold` / `--compact-threshold`; 0 = manual only).
    pub fn with_compact_threshold(mut self, threshold: usize) -> Self {
        self.compact_threshold = threshold;
        self
    }

    /// Attach a generation-keyed result cache bounded to `mb` MiB (config
    /// key `result_cache_mb` / `--result-cache-mb`; 0 = off). Cacheable
    /// verbs (`RULES`/`EXPLAIN`/`FIND`/`TOP`/`CONSEQ`/`SUPPORT`, minus
    /// `ANALYZE` runs) answer repeated request lines from memory; every
    /// serving-view install invalidates wholesale, so a stale answer is
    /// never served (`rust/tests/service_fanout.rs` gates byte parity with
    /// a cache-less engine across INGEST and COMPACT swaps).
    pub fn with_result_cache(mut self, mb: usize) -> Self {
        self.cache = (mb > 0).then(|| ResultCache::with_capacity_mb(mb));
        self
    }

    /// Rebind the engine's observability plane onto an external registry
    /// (so build-pipeline metrics and serving metrics land in one
    /// exposition) and optionally attach a JSONL telemetry exporter. Also
    /// binds the worker pool's counters into the same registry.
    pub fn with_observability(
        mut self,
        registry: Arc<MetricsRegistry>,
        exporter: Option<Arc<TelemetryExporter>>,
    ) -> Self {
        self.exec.pool().bind_metrics(&registry);
        let enabled = self.obs.enabled;
        self.obs = ServiceObs::new(registry, exporter);
        self.obs.enabled = enabled;
        self
    }

    /// Toggle per-request instrumentation (clock reads, counters, exporter
    /// records). `METRICS`/`STATS` keep working either way; response bytes
    /// for every verb except `STATS`' counters are identical on both
    /// settings — that parity is what `benches/obs_overhead.rs` gates on.
    pub fn with_metrics_enabled(mut self, enabled: bool) -> Self {
        self.obs.enabled = enabled;
        self
    }

    /// The engine's metrics registry (for embedding, tests, and benches).
    pub fn metrics_registry(&self) -> &Arc<MetricsRegistry> {
        &self.obs.registry
    }

    /// Pin the current serving state.
    pub fn view(&self) -> Arc<MergedView> {
        Arc::clone(&self.serving.lock().unwrap().view)
    }

    /// Pin the serving view *and* its cache generation atomically (one
    /// lock), so a cached entry can never be stored or served against the
    /// wrong snapshot.
    fn pinned(&self) -> (u64, Arc<MergedView>) {
        let serving = self.serving.lock().unwrap();
        (serving.generation, Arc::clone(&serving.view))
    }

    /// Install a freshly built serving view: swap the `Arc` and advance
    /// the generation under one lock, then clear the result cache. A query
    /// racing this install may have pinned the old view and can insert a
    /// stale-generation entry *after* the clear; such stragglers are
    /// memory-bounded noise — [`ResultCache::get`] evicts them on contact
    /// and never serves them.
    fn install_view(&self, view: Arc<MergedView>) {
        {
            let mut serving = self.serving.lock().unwrap();
            serving.view = view;
            serving.generation += 1;
        }
        if let Some(cache) = &self.cache {
            let invalidated = cache.clear();
            if self.obs.enabled {
                self.obs.result_cache_invalidations.add(invalidated);
                self.obs.result_cache_bytes.set(0);
                self.obs.result_cache_entries.set(0);
            }
        }
    }

    /// Live-connection gauge handle for the TCP front ends.
    pub(crate) fn conn_gauge(&self) -> Gauge {
        self.obs.active_conns.clone()
    }

    /// Record one admission-control shed (a `BUSY` response).
    pub(crate) fn note_shed(&self) {
        if self.obs.enabled {
            self.obs.shed_requests.inc();
        }
    }

    /// Record one idle-timeout connection eviction.
    pub(crate) fn note_idle_evicted(&self) {
        if self.obs.enabled {
            self.obs.idle_evicted_conns.inc();
        }
    }

    /// The current frozen base snapshot.
    pub fn base_trie(&self) -> Arc<TrieOfRules> {
        Arc::clone(&self.view().base)
    }

    /// Effective degree of query parallelism (STATS `threads=`).
    pub fn threads(&self) -> usize {
        self.exec.degree()
    }

    pub fn queries_served(&self) -> u64 {
        self.queries.load(Ordering::Relaxed)
    }

    /// Execute one text command, returning the response line(s).
    ///
    /// When instrumentation is enabled the dispatch is wrapped in one
    /// clock-read pair feeding the verb's latency histogram and (if
    /// attached) a `query` telemetry record; the response bytes are the
    /// same either way.
    pub fn execute(&self, line: &str) -> String {
        self.queries.fetch_add(1, Ordering::Relaxed);
        let line = line.trim();
        let (cmd, rest) = line.split_once(' ').unwrap_or((line, ""));
        let cmd = cmd.to_ascii_uppercase();
        let verb = Verb::of(&cmd);
        let t0 = self.obs.enabled.then(Instant::now);
        let resp = if self.cache.is_some() && cacheable(verb, line) {
            self.execute_cached(verb, line, rest)
        } else {
            match cmd.as_str() {
                "RULES" | "EXPLAIN" => self.cmd_rql(line, &self.view()),
                "FIND" => self.cmd_find(rest, &self.view()),
                "TOP" => self.cmd_top(rest, &self.view()),
                "SUPPORT" => self.cmd_support(rest, &self.view()),
                "CONSEQ" => self.cmd_conseq(rest, &self.view()),
                "INGEST" => self.cmd_ingest(rest),
                "COMPACT" => self.cmd_compact(),
                "SNAPSHOT" => self.cmd_snapshot(rest),
                "STATS" => self.cmd_stats(),
                "METRICS" => self.cmd_metrics(rest),
                "SCATTER" => self.cmd_scatter(rest),
                "QUIT" => "BYE".to_string(),
                other => format!("ERR unknown command `{other}`"),
            }
        };
        if let Some(t0) = t0 {
            let latency = t0.elapsed();
            self.obs.verb_count[verb as usize].inc();
            self.obs.verb_latency[verb as usize].observe_duration(latency);
            if let Some(exporter) = &self.obs.exporter {
                let ok = !resp.starts_with("ERR");
                exporter.emit_query(verb.name(), latency, ok, self.view().epoch);
            }
        }
        resp
    }

    /// Cache-aware path for the pure query verbs: pin `(generation, view)`
    /// once, answer from the cache on a hit, record the rendered response
    /// on a miss. Hits skip execution but keep full verb accounting (the
    /// caller's latency/counter block runs either way).
    fn execute_cached(&self, verb: Verb, line: &str, rest: &str) -> String {
        let cache = self.cache.as_ref().expect("caller checked cache presence");
        let (generation, view) = self.pinned();
        if let Some(hit) = cache.get(generation, line) {
            if self.obs.enabled {
                self.obs.result_cache_hits.inc();
            }
            return hit.to_string();
        }
        if self.obs.enabled {
            self.obs.result_cache_misses.inc();
        }
        let resp = match verb {
            Verb::Rules | Verb::Explain => self.cmd_rql(line, &view),
            Verb::Find => self.cmd_find(rest, &view),
            Verb::Top => self.cmd_top(rest, &view),
            Verb::Conseq => self.cmd_conseq(rest, &view),
            Verb::Support => self.cmd_support(rest, &view),
            _ => unreachable!("cacheable() admits query verbs only"),
        };
        // Errors are not cached: they are cheap to recompute and would
        // otherwise occupy LRU space proportional to client typos.
        if !resp.starts_with("ERR") {
            let evicted = cache.insert(generation, line, &resp);
            if self.obs.enabled {
                self.obs.result_cache_evictions.add(evicted);
                self.obs.result_cache_bytes.set(cache.bytes() as i64);
                self.obs.result_cache_entries.set(cache.len() as i64);
            }
        }
        resp
    }

    /// Execute a full RQL line through the query engine on a pinned view.
    fn cmd_rql(&self, line: &str, view: &MergedView) -> String {
        let query = match crate::query::parser::parse(line) {
            Ok(q) => q,
            Err(e) => return format!("ERR {e:#}"),
        };
        match self.exec.execute_view(view, &self.vocab, &query) {
            Err(e) => format!("ERR {e:#}"),
            Ok(QueryOutput::Explain(text)) => {
                // Self-delimiting like every multi-line response: the
                // header carries the body's line count.
                let body = text.trim_end();
                format!("EXPLAIN {}\n{body}", body.lines().count())
            }
            Ok(QueryOutput::Rows(rs)) => {
                let mut out = format!("RULES {}\n", rs.rows.len());
                let extra = extra_metric(&query);
                for row in &rs.rows {
                    out.push_str(&render_rule_row(row, &self.vocab, extra));
                    out.push('\n');
                }
                out.pop();
                out
            }
        }
    }

    /// `SCATTER k/n <RULES ...>`: execute only partition `k` of `n` of a
    /// plain RULES query and answer with a machine-mergeable `PARTIAL`
    /// frame (DESIGN.md §18) — the shard half of scatter-gather serving.
    /// The header carries this partition's row count, the serving cache
    /// generation (the coordinator asserts all shards answered from the
    /// same install), and the partition's exact work counters; each row
    /// line carries the rule's item ids, the ten metric f64s as hex bit
    /// patterns (lossless — the merge re-sorts under `f64::total_cmp`),
    /// and the row pre-rendered through the same [`render_rule_row`] the
    /// local RULES path uses, so the coordinator's merged response is
    /// byte-identical to a single-node engine's without needing the vocab.
    fn cmd_scatter(&self, rest: &str) -> String {
        const USAGE: &str = "ERR usage: SCATTER <k>/<n> <RULES ...>";
        let Some((spec, rql)) = rest.trim().split_once(' ') else {
            return USAGE.to_string();
        };
        let Some((k, n)) = spec.split_once('/') else {
            return USAGE.to_string();
        };
        let (Ok(k), Ok(n)) = (k.parse::<usize>(), n.parse::<usize>()) else {
            return USAGE.to_string();
        };
        if n == 0 || k >= n {
            return format!("ERR shard {k}/{n} out of range");
        }
        if let Some((me, of)) = self.shard_of {
            if of != n || me != k {
                return format!("ERR shard identity mismatch: this shard is {me}/{of}");
            }
        }
        let query = match crate::query::parser::parse(rql) {
            Ok(q) => q,
            Err(e) => return format!("ERR {e:#}"),
        };
        if query.explain || query.analyze {
            return "ERR EXPLAIN cannot be scattered".to_string();
        }
        let (generation, view) = self.pinned();
        let rs = match self
            .exec
            .execute_view_partition(&view, &self.vocab, &query, k, n)
        {
            Ok(rs) => rs,
            Err(e) => return format!("ERR {e:#}"),
        };
        let extra = extra_metric(&query);
        let mut out = format!(
            "PARTIAL {} gen={} scanned={} candidates={} matched={}",
            rs.rows.len(),
            generation,
            rs.stats.scanned,
            rs.stats.candidates,
            rs.stats.matched
        );
        for row in &rs.rows {
            out.push('\n');
            out.push_str(&super::scatter::encode_partial_row(
                row,
                &render_rule_row(row, &self.vocab, extra),
            ));
        }
        out
    }

    fn parse_items(&self, s: &str) -> Result<Vec<u32>> {
        s.split(',')
            .map(|name| {
                let name = name.trim();
                self.vocab
                    .get(name)
                    .with_context(|| format!("unknown item `{name}`"))
            })
            .collect()
    }

    fn cmd_find(&self, rest: &str, view: &MergedView) -> String {
        let Some((a, c)) = rest.split_once("=>") else {
            return "ERR usage: FIND a,b => c".to_string();
        };
        let (a, c) = match (self.parse_items(a), self.parse_items(c)) {
            (Ok(a), Ok(c)) if !a.is_empty() && !c.is_empty() => (a, c),
            (Err(e), _) | (_, Err(e)) => return format!("ERR {e}"),
            _ => return "ERR empty rule side".to_string(),
        };
        if a.iter().any(|i| c.contains(i)) {
            return "ERR overlapping rule sides".to_string();
        }
        match view.find_rule(&Rule::from_ids(a, c)) {
            FindOutcome::Found(m) => format!(
                "FOUND sup={:.6} conf={:.6} lift={:.4} lev={:.6} conv={:.4}",
                m.support, m.confidence, m.lift, m.leverage, m.conviction
            ),
            FindOutcome::NotRepresentable => "NOTREP".to_string(),
            FindOutcome::Absent => "ABSENT".to_string(),
        }
    }

    /// Desugar a legacy command straight to the RQL AST (no text
    /// round-trip, so item names never need re-quoting) and execute it.
    fn run_desugared(&self, query: &RqlQuery, view: &MergedView) -> Result<Vec<Row>, String> {
        match self.exec.execute_view(view, &self.vocab, query) {
            Ok(QueryOutput::Rows(rs)) => Ok(rs.rows),
            Ok(QueryOutput::Explain(_)) => unreachable!("desugared commands never explain"),
            Err(e) => Err(format!("ERR {e:#}")),
        }
    }

    /// Legacy sugar: `TOP m k` desugars to `RULES SORT BY m DESC LIMIT k`
    /// and runs through the RQL engine (response format unchanged). The
    /// population is every representable rule, so compound-consequent
    /// rules rank too (the pre-RQL command saw stored node-rules only).
    fn cmd_top(&self, rest: &str, view: &MergedView) -> String {
        let mut parts = rest.split_whitespace();
        let Some(metric) = parts.next().and_then(Metric::parse) else {
            return "ERR usage: TOP <metric> <k>".to_string();
        };
        let Some(k) = parts.next().and_then(|s| s.parse::<usize>().ok()) else {
            return "ERR usage: TOP <metric> <k>".to_string();
        };
        let query = RqlQuery {
            explain: false,
            analyze: false,
            preds: Vec::new(),
            sort: Some(SortSpec {
                metric,
                descending: true,
            }),
            limit: Some(k),
        };
        let rows = match self.run_desugared(&query, view) {
            Ok(rows) => rows,
            Err(e) => return e,
        };
        let mut out = format!("TOP {} {}\n", metric.name(), rows.len());
        for row in rows {
            out.push_str(&format!(
                "  {} {}={:.6}\n",
                row.rule.display(&self.vocab),
                metric.name(),
                row.metrics.get(metric)
            ));
        }
        out.pop();
        out
    }

    fn cmd_support(&self, rest: &str, view: &MergedView) -> String {
        match self.parse_items(rest) {
            Ok(items) if !items.is_empty() => match view.support_of(&items) {
                Some(c) => format!("SUPPORT {c}"),
                None => "ABSENT".to_string(),
            },
            Ok(_) => "ERR empty itemset".to_string(),
            Err(e) => format!("ERR {e}"),
        }
    }

    /// Legacy sugar: `CONSEQ c` desugars to `RULES WHERE conseq = c` — the
    /// planner answers it via the consequent header-list access path, the
    /// same structure `rules_with_consequent` read directly. Desugaring is
    /// AST-level, so item names the RQL surface syntax cannot quote (e.g.
    /// containing `'`) still resolve exactly as they did pre-RQL.
    fn cmd_conseq(&self, rest: &str, view: &MergedView) -> String {
        let item = rest.trim();
        let query = RqlQuery {
            explain: false,
            analyze: false,
            preds: vec![Pred::ConseqEq(item.to_string())],
            sort: None,
            limit: None,
        };
        let rows = match self.run_desugared(&query, view) {
            Ok(rows) => rows,
            Err(e) => return e,
        };
        let mut out = format!("CONSEQ {item} {}\n", rows.len());
        for row in rows.iter().take(50) {
            let names = row
                .rule
                .antecedent
                .items()
                .iter()
                .map(|&i| self.vocab.name(i))
                .collect::<Vec<_>>()
                .join(",");
            out.push_str(&format!(
                "  {{{names}}} => {{{item}}} conf={:.4}\n",
                row.metrics.confidence
            ));
        }
        out.pop();
        out
    }

    /// `INGEST a,b,c;d,e`: absorb a `;`-separated batch of transactions
    /// into the incremental store, rebuild the delta overlay, auto-compact
    /// at the configured threshold, and swap the serving view.
    fn cmd_ingest(&self, rest: &str) -> String {
        let Some(store) = &self.store else {
            return "ERR INGEST requires an incremental engine (a pipeline-built service \
                    retains its base database; a trie loaded from disk cannot ingest)"
                .to_string();
        };
        if rest.trim().is_empty() {
            return "ERR usage: INGEST a,b,c[;d,e...]".to_string();
        }
        let mut txs: Vec<Vec<u32>> = Vec::new();
        for part in rest.split(';') {
            match self.parse_items(part) {
                Ok(items) if !items.is_empty() => txs.push(items),
                Ok(_) => return "ERR empty transaction".to_string(),
                Err(e) => return format!("ERR {e}"),
            }
        }
        let mut store = store.lock().unwrap();
        // Durability barrier: the batch must be WAL-logged *before* it is
        // applied or acknowledged (log order = apply order because both
        // happen under the store lock). A log failure refuses the batch
        // and flips the service read-only instead of panicking.
        if let Some((plane, dobs)) = &self.durability {
            if let Err(e) = plane.log_ingest(store.epoch(), &txs) {
                dobs.refresh(plane);
                return format!("ERR degraded (read-only, mutation refused): {e:#}");
            }
            dobs.refresh(plane);
        }
        let report = match store.ingest(&txs) {
            Ok(r) => r,
            Err(e) => return format!("ERR {e:#}"),
        };
        // The ingest itself succeeded; whatever happens to the optional
        // auto-compaction below, the new view must be swapped in and the
        // response must say OK — otherwise a client retry would double-
        // count the batch.
        let mut suffix = String::new();
        if self.compact_threshold > 0 && store.pending_len() >= self.compact_threshold {
            let pause_t = self.obs.enabled.then(Instant::now);
            match store.compact(Some(self.exec.pool())) {
                Ok(true) => {
                    suffix = " compacted".to_string();
                    if let Some(msg) = self.log_compact(&store) {
                        suffix.push_str(&msg);
                    }
                    if let Some(t0) = pause_t {
                        let pause = t0.elapsed();
                        self.obs.compact_pause_seconds.observe_duration(pause);
                        if let Some(exporter) = &self.obs.exporter {
                            exporter.emit_compact(
                                pause,
                                store.base().num_nodes(),
                                store.compactions(),
                                store.epoch(),
                            );
                        }
                    }
                }
                Ok(false) => {}
                Err(e) => suffix = format!(" (auto-compaction failed: {e:#})"),
            }
        }
        self.install_view(Arc::new(store.view()));
        if self.obs.enabled {
            self.obs.ingest_batch_tx.observe(txs.len() as u64);
            self.obs.epoch.set(store.epoch() as i64);
            self.obs.pending_tx.set(store.pending_len() as i64);
            self.obs.delta_nodes.set(store.delta_nodes() as i64);
            if let Some(exporter) = &self.obs.exporter {
                exporter.emit_ingest(
                    txs.len(),
                    store.pending_len(),
                    store.delta_nodes(),
                    store.epoch(),
                );
                exporter.emit_snapshot_swap(
                    store.delta_nodes(),
                    store.pending_len(),
                    store.epoch(),
                );
                exporter.flush();
            }
        }
        format!(
            "OK ingested={} pending={} delta_nodes={} epoch={}{suffix}",
            report.ingested,
            store.pending_len(),
            store.delta_nodes(),
            store.epoch()
        )
    }

    /// Record a completed compaction on the durability plane: barrier
    /// record, forced fsync, fresh checkpoint, log truncation. Returns a
    /// response suffix when the plane failed — the compaction itself
    /// already happened and keeps serving, but further mutations are
    /// refused (degraded mode).
    fn log_compact(&self, store: &IncrementalTrie) -> Option<String> {
        let (plane, dobs) = self.durability.as_ref()?;
        let out = match plane.log_compact_and_checkpoint(store) {
            Ok(()) => None,
            Err(e) => Some(format!(" (durability degraded: {e:#})")),
        };
        dobs.refresh(plane);
        out
    }

    /// `COMPACT`: merge the pending delta into a fresh frozen snapshot on
    /// the shared worker pool and swap it in atomically.
    fn cmd_compact(&self) -> String {
        let Some(store) = &self.store else {
            return "ERR COMPACT requires an incremental engine".to_string();
        };
        let mut store = store.lock().unwrap();
        if let Some((plane, _)) = &self.durability {
            if plane.is_degraded() {
                return format!(
                    "ERR degraded (read-only, mutation refused): {}",
                    plane.last_error().unwrap_or_else(|| "durability failure".into())
                );
            }
        }
        let pause_t = self.obs.enabled.then(Instant::now);
        match store.compact(Some(self.exec.pool())) {
            Ok(true) => {
                let durability_suffix = self.log_compact(&store).unwrap_or_default();
                self.install_view(Arc::new(store.view()));
                if let Some(t0) = pause_t {
                    let pause = t0.elapsed();
                    self.obs.compact_pause_seconds.observe_duration(pause);
                    self.obs.epoch.set(store.epoch() as i64);
                    self.obs.pending_tx.set(store.pending_len() as i64);
                    self.obs.delta_nodes.set(store.delta_nodes() as i64);
                    if let Some(exporter) = &self.obs.exporter {
                        exporter.emit_compact(
                            pause,
                            store.base().num_nodes(),
                            store.compactions(),
                            store.epoch(),
                        );
                        exporter.emit_snapshot_swap(
                            store.delta_nodes(),
                            store.pending_len(),
                            store.epoch(),
                        );
                        exporter.emit_metrics(&self.obs.registry, store.epoch());
                        exporter.flush();
                    }
                }
                format!(
                    "OK compacted epoch={} nodes={} compactions={}{durability_suffix}",
                    store.epoch(),
                    store.base().num_nodes(),
                    store.compactions()
                )
            }
            Ok(false) => format!("OK epoch={} pending=0 (nothing to compact)", store.epoch()),
            Err(e) => format!("ERR {e:#}"),
        }
    }

    /// `SNAPSHOT /path`: persist the current frozen base (v4 succinct
    /// columnar; copy-on-write when the base is itself an `mmap`'d v4
    /// image) and, when updates are pending, a `<path>.delta` sidecar
    /// holding the uncompacted transaction tail.
    fn cmd_snapshot(&self, rest: &str) -> String {
        let path = rest.trim();
        if path.is_empty() {
            return "ERR usage: SNAPSHOT <path>".to_string();
        }
        let path = std::path::PathBuf::from(path);
        match &self.store {
            Some(store) => {
                let store = store.lock().unwrap();
                if let Err(e) =
                    crate::trie::serialize::save(store.base(), Some(&self.vocab), &path)
                {
                    return format!("ERR {e:#}");
                }
                let mut extra = String::new();
                let sidecar = sidecar_path(&path);
                if store.pending_len() > 0 {
                    if let Err(e) = crate::trie::serialize::save_delta(
                        &sidecar,
                        store.epoch(),
                        store.minsup(),
                        store.pending(),
                    ) {
                        return format!("ERR {e:#}");
                    }
                    extra = format!(" sidecar={}", sidecar.display());
                } else {
                    // Nothing pending: remove any sidecar a previous
                    // snapshot to the same path left behind, so the pair
                    // on disk can never describe two different epochs.
                    std::fs::remove_file(&sidecar).ok();
                }
                if self.obs.enabled {
                    if let Some(exporter) = &self.obs.exporter {
                        exporter.emit_snapshot(
                            &path.display().to_string(),
                            store.pending_len(),
                            store.epoch(),
                        );
                        exporter.flush();
                    }
                }
                format!(
                    "OK snapshot={} epoch={} pending={}{extra}",
                    path.display(),
                    store.epoch(),
                    store.pending_len()
                )
            }
            None => {
                let view = self.view();
                match crate::trie::serialize::save(&view.base, Some(&self.vocab), &path) {
                    Ok(()) => {
                        if self.obs.enabled {
                            if let Some(exporter) = &self.obs.exporter {
                                exporter.emit_snapshot(&path.display().to_string(), 0, view.epoch);
                                exporter.flush();
                            }
                        }
                        format!(
                            "OK snapshot={} epoch={} pending=0",
                            path.display(),
                            view.epoch
                        )
                    }
                    Err(e) => format!("ERR {e:#}"),
                }
            }
        }
    }

    /// `STATS`: counters over the serving state. `mem_kib` is exact, not
    /// estimated — the columnar layout's footprint is the sum of its
    /// column lengths times element widths (node columns + ten metric
    /// columns + child CSR + header CSR; see
    /// [`TrieOfRules::memory_bytes`] and DESIGN.md §8). The incremental
    /// tail reports the snapshot epoch, the pending-transaction count, the
    /// delta overlay size, and how many compactions have run.
    fn cmd_stats(&self) -> String {
        let view = self.view();
        let (pending, delta_nodes, compactions) = match &self.store {
            Some(store) => {
                let store = store.lock().unwrap();
                (store.pending_len(), store.delta_nodes(), store.compactions())
            }
            None => (0, 0, 0),
        };
        let mut out = format!(
            "STATS nodes={} rules={} mem_kib={} threads={} build_threads={} queries={} \
             epoch={} pending_tx={} delta_nodes={} compactions={}",
            view.base.num_nodes(),
            view.base.num_representable_rules(),
            view.base.memory_bytes() / 1024,
            self.threads(),
            self.build_threads,
            self.queries_served(),
            view.epoch,
            pending,
            delta_nodes,
            compactions
        );
        // Observability tail (append-only so the pre-existing key order
        // stays stable for scrapers): wall uptime, live TCP connections,
        // and the per-verb request counters in Verb::ALL order. The
        // counters exclude the STATS request being answered — its verb
        // accounting happens after the response is built.
        out.push_str(&format!(
            " uptime_s={} active_conns={}",
            self.obs.uptime_s(),
            self.obs.active_conns.get()
        ));
        for verb in Verb::ALL {
            out.push_str(&format!(
                " q_{}={}",
                verb.name(),
                self.obs.verb_count[verb as usize].get()
            ));
        }
        // Front-end tail (append-only, like the block above): admission
        // sheds, idle evictions, and the result cache's counters.
        out.push_str(&format!(
            " shed={} idle_evicted={} cache_hits={} cache_misses={} cache_evictions={} \
             cache_entries={}",
            self.obs.shed_requests.get(),
            self.obs.idle_evicted_conns.get(),
            self.obs.result_cache_hits.get(),
            self.obs.result_cache_misses.get(),
            self.obs.result_cache_evictions.get(),
            self.cache.as_ref().map_or(0, |c| c.len())
        ));
        // Storage-backend tail (append-only): which ColumnStore serves the
        // base and how many bytes are mmap'd (0 for the owned backend,
        // where mem_kib above is the whole story; for mmap, mem_kib is the
        // resident side-structure footprint and mapped_kib the image).
        out.push_str(&format!(
            " backend={} mapped_kib={}",
            view.base.backend_name(),
            view.base.mapped_bytes() / 1024
        ));
        // Durability tail: appended ONLY when a plane is attached, so a
        // WAL-less engine's STATS bytes are identical to before.
        if let Some((plane, dobs)) = &self.durability {
            dobs.refresh(plane);
            out.push_str(&plane.stats_fields());
        }
        // Shard-identity tail: appended ONLY under `--shard-of`, so a
        // standalone engine's STATS bytes are unchanged.
        if let Some((k, n)) = self.shard_of {
            out.push_str(&format!(" shard={k}/{n}"));
        }
        out
    }

    /// `METRICS` — the full registry in Prometheus text exposition,
    /// self-delimiting like every multi-line response (`METRICS <n>` header
    /// carrying the body's line count). `METRICS JSON` — the same snapshot
    /// as one compact JSON line (`METRICS JSON {...}`), parseable with
    /// `util::json`.
    fn cmd_metrics(&self, rest: &str) -> String {
        // Refresh the point-in-time gauges so a scrape is never staler
        // than the request that asked for it.
        self.obs.uptime_s();
        let view = self.view();
        self.obs.epoch.set(view.epoch as i64);
        if let Some(store) = &self.store {
            let store = store.lock().unwrap();
            self.obs.pending_tx.set(store.pending_len() as i64);
            self.obs.delta_nodes.set(store.delta_nodes() as i64);
        }
        match rest.trim().to_ascii_uppercase().as_str() {
            "" => {
                let body = self.obs.registry.render_prometheus();
                let body = body.trim_end();
                format!("METRICS {}\n{body}", body.lines().count())
            }
            "JSON" => format!(
                "METRICS JSON {}",
                self.obs.registry.to_json().to_string_compact()
            ),
            _ => "ERR usage: METRICS [JSON]".to_string(),
        }
    }
}

/// The extra sort-metric column a RULES rendering carries: the sort
/// metric, unless it is one of the three always-printed metrics.
pub(crate) fn extra_metric(query: &RqlQuery) -> Option<Metric> {
    query
        .sort
        .map(|s| s.metric)
        .filter(|m| !matches!(*m, Metric::Support | Metric::Confidence | Metric::Lift))
}

/// Render one result row exactly as `RULES` responses print it (no
/// trailing newline). Shared by the local RQL path and the `SCATTER`
/// partial frames, so a scatter-gather coordinator can merge pre-rendered
/// rows into a byte-identical `RULES` response without holding the vocab.
pub(crate) fn render_rule_row(row: &Row, vocab: &Vocab, extra: Option<Metric>) -> String {
    let mut out = format!(
        "  {} sup={:.6} conf={:.6} lift={:.4}",
        row.rule.display(vocab),
        row.metrics.support,
        row.metrics.confidence,
        row.metrics.lift
    );
    if let Some(m) = extra {
        out.push_str(&format!(" {}={:.6}", m.name(), row.metrics.get(m)));
    }
    out
}

/// Sidecar path for a snapshot's pending-delta tail: `<path>.delta`.
fn sidecar_path(path: &std::path::Path) -> std::path::PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".delta");
    std::path::PathBuf::from(s)
}

/// Serve the engine over TCP until `shutdown` flips true. Binds `addr`
/// (e.g. `127.0.0.1:7878`); returns the bound address (port 0 supported).
///
/// This is the nonblocking front end (`coordinator/frontend.rs`) with
/// default options — one acceptor plus auto-sized event-loop shards,
/// admission control, text/`RQL2` negotiation. Use
/// [`frontend::serve_nonblocking`] directly to tune shards, the pending
/// bound, or the idle timeout; [`serve_tcp_blocking`] keeps the original
/// thread-per-connection server as the parity baseline.
pub fn serve_tcp(
    engine: Arc<QueryEngine>,
    addr: &str,
    shutdown: Arc<AtomicBool>,
) -> Result<std::net::SocketAddr> {
    frontend::serve_nonblocking(engine, addr, shutdown, ServeOptions::default())
}

/// The original thread-per-connection blocking server. Retained (not
/// dead code) as the byte-parity baseline the nonblocking front end is
/// gated against in `benches/service_fanout.rs` and
/// `rust/tests/service_fanout.rs`, and for minimal embeddings that want
/// one thread per client.
pub fn serve_tcp_blocking(
    engine: Arc<QueryEngine>,
    addr: &str,
    shutdown: Arc<AtomicBool>,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    std::thread::spawn(move || {
        let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !shutdown.load(Ordering::Relaxed) {
            // Reap finished connection handlers each iteration so a
            // long-lived server holds O(live connections) handles, not one
            // per connection ever accepted.
            let mut i = 0;
            while i < workers.len() {
                if workers[i].is_finished() {
                    workers.swap_remove(i).join().ok();
                } else {
                    i += 1;
                }
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let engine = Arc::clone(&engine);
                    // Counted on accept (not inside the handler thread) so
                    // the gauge never under-reports a connection that is
                    // alive but not yet scheduled; the guard decrements on
                    // every exit path of the handler.
                    engine.obs.active_conns.add(1);
                    let guard = ConnGuard(engine.obs.active_conns.clone());
                    workers.push(std::thread::spawn(move || {
                        let _guard = guard;
                        let _ = handle_client(stream, &engine);
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(_) => break,
            }
        }
        for w in workers {
            w.join().ok();
        }
        // Same orderly-stop drain as the nonblocking front end.
        engine.shutdown_flush();
    });
    Ok(local)
}

/// Decrements the active-connection gauge when a handler thread exits,
/// whether the client said QUIT, hung up, or the stream errored.
struct ConnGuard(Gauge);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.sub(1);
    }
}

fn handle_client(stream: TcpStream, engine: &QueryEngine) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        // Read one line, but never more than the request cap (+1 so an
        // exactly-at-cap line that *is* terminated still passes): a client
        // streaming garbage without a newline used to grow this buffer
        // without bound.
        buf.clear();
        let n = reader
            .by_ref()
            .take(MAX_REQUEST_BYTES as u64 + 1)
            .read_until(b'\n', &mut buf)?;
        if n == 0 {
            break; // EOF
        }
        if !buf.ends_with(b"\n") && buf.len() > MAX_REQUEST_BYTES {
            writer.write_all(b"ERR line too long\n")?;
            break; // drop the connection
        }
        if buf.last() == Some(&b'\n') {
            buf.pop();
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
        }
        // `BufRead::lines` aborted the connection on invalid UTF-8; keep
        // that behavior (silent close, no response).
        let Ok(line) = std::str::from_utf8(&buf) else {
            break;
        };
        let resp = engine.execute(line);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        if resp == "BYE" {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::paper_example_db;
    use crate::mining::counts::{min_count, ItemOrder};
    use crate::mining::fpgrowth::fpgrowth;

    fn engine() -> QueryEngine {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        QueryEngine::new(trie, db.vocab().clone())
    }

    #[test]
    fn find_command() {
        let e = engine();
        let resp = e.execute("FIND f,c => a");
        assert!(resp.starts_with("FOUND"), "{resp}");
        assert!(resp.contains("conf=1.000000"), "{resp}");
        assert_eq!(e.execute("FIND a => f"), "NOTREP");
        assert_eq!(e.execute("FIND f => d"), "ABSENT");
        assert!(e.execute("FIND f => f").starts_with("ERR"));
        assert!(e.execute("FIND nosuchitem => f").starts_with("ERR"));
        assert!(e.execute("FIND f c").starts_with("ERR usage"));
    }

    #[test]
    fn top_command() {
        let e = engine();
        let resp = e.execute("TOP support 3");
        assert!(resp.starts_with("TOP support 3"), "{resp}");
        assert_eq!(resp.lines().count(), 4);
        assert!(e.execute("TOP bogus 3").starts_with("ERR"));
    }

    #[test]
    fn support_and_conseq_commands() {
        let e = engine();
        assert_eq!(e.execute("SUPPORT f,c"), "SUPPORT 3");
        assert_eq!(e.execute("SUPPORT d"), "ABSENT");
        let resp = e.execute("CONSEQ a");
        assert!(resp.starts_with("CONSEQ a"), "{resp}");
        assert!(resp.lines().count() > 1);
    }

    #[test]
    fn rules_command_routes_through_rql() {
        let e = engine();
        let resp = e.execute("RULES WHERE conseq = a SORT BY lift DESC LIMIT 5");
        assert!(resp.starts_with("RULES "), "{resp}");
        let n: usize = resp
            .lines()
            .next()
            .unwrap()
            .strip_prefix("RULES ")
            .unwrap()
            .parse()
            .unwrap();
        assert!(n >= 1, "{resp}");
        assert_eq!(resp.lines().count(), n + 1, "{resp}");
        assert!(resp.contains("=> {a}"), "{resp}");
        // Sort metric outside sup/conf/lift is appended to each row.
        let resp = e.execute("RULES SORT BY leverage DESC LIMIT 2");
        assert!(resp.contains("leverage="), "{resp}");
        // Errors surface as ERR lines, like every other command.
        assert!(e.execute("RULES WHERE conseq = nosuch").starts_with("ERR"));
        assert!(e.execute("RULES WHERE bogus >= 1").starts_with("ERR"));
    }

    #[test]
    fn explain_command_shows_plan_and_is_self_delimiting() {
        let e = engine();
        let resp = e.execute("EXPLAIN RULES WHERE conseq = a AND support >= 0.4 LIMIT 3");
        let header = resp.lines().next().unwrap();
        let n: usize = header.strip_prefix("EXPLAIN ").unwrap().parse().unwrap();
        assert_eq!(resp.lines().count(), n + 1, "{resp}");
        assert!(resp.contains("conseq-header(a)"), "{resp}");
        assert!(resp.contains("subtree cutoff"), "{resp}");
        let resp = e.execute("EXPLAIN RULES");
        assert!(resp.contains("full-traversal"), "{resp}");
    }

    #[test]
    fn conseq_desugar_handles_names_rql_cannot_quote() {
        // AST-level desugar: a vocab name containing a single quote is
        // unexpressable in RQL surface syntax but must keep working
        // through the legacy CONSEQ command (as it did pre-RQL).
        let e = engine();
        let resp = e.execute("CONSEQ men's wallet");
        assert!(
            resp.starts_with("ERR unknown item `men's wallet`"),
            "{resp}"
        );
    }

    #[test]
    fn desugared_top_matches_rql() {
        let e = engine();
        let legacy = e.execute("TOP confidence 4");
        let rql = e.execute("RULES SORT BY confidence DESC LIMIT 4");
        // Same rules, same order — only the header/row dressing differs.
        assert_eq!(legacy.lines().count(), rql.lines().count());
        for (l, r) in legacy.lines().skip(1).zip(rql.lines().skip(1)) {
            let rule_of = |s: &str| s.trim().split(" => ").next().unwrap().to_string();
            assert_eq!(rule_of(l), rule_of(r), "{legacy}\nvs\n{rql}");
        }
    }

    #[test]
    fn stats_and_counter() {
        let e = engine();
        e.execute("FIND f => c");
        let resp = e.execute("STATS");
        assert!(resp.contains("nodes="), "{resp}");
        assert!(
            resp.contains(&format!("threads={}", e.threads())),
            "{resp}"
        );
        // No pipeline ran here, so the build thread count is unknown (0).
        assert!(resp.contains("build_threads=0"), "{resp}");
        assert!(e.queries_served() >= 2);
    }

    #[test]
    fn stats_carries_observability_tail() {
        let e = engine();
        e.execute("FIND f,c => a");
        e.execute("RULES LIMIT 1");
        let resp = e.execute("STATS");
        assert!(resp.contains(" uptime_s="), "{resp}");
        assert!(resp.contains(" active_conns=0"), "{resp}");
        assert!(resp.contains(" q_rules=1"), "{resp}");
        assert!(resp.contains(" q_find=1"), "{resp}");
        // The STATS being answered is counted after its response renders.
        assert!(resp.contains(" q_stats=0"), "{resp}");
        let resp = e.execute("STATS");
        assert!(resp.contains(" q_stats=1"), "{resp}");
        // The tail keys come in fixed Verb::ALL order.
        let tail: Vec<&str> = resp
            .split_whitespace()
            .filter(|t| t.starts_with("q_"))
            .collect();
        assert_eq!(tail.len(), 13, "{resp}");
        assert!(tail[0].starts_with("q_rules="), "{resp}");
        assert!(tail[11].starts_with("q_other="), "{resp}");
        assert!(tail[12].starts_with("q_scatter="), "{resp}");
    }

    #[test]
    fn metrics_command_serves_prometheus_summaries() {
        let e = engine();
        e.execute("RULES LIMIT 1");
        e.execute("FIND f,c => a");
        let resp = e.execute("METRICS");
        let header = resp.lines().next().unwrap();
        let n: usize = header.strip_prefix("METRICS ").unwrap().parse().unwrap();
        assert_eq!(resp.lines().count(), n + 1, "{resp}");
        assert!(
            resp.contains("tor_queries_total{verb=\"rules\"} 1"),
            "{resp}"
        );
        assert!(resp.contains("# TYPE tor_query_seconds summary"), "{resp}");
        for q in ["0.5", "0.99", "0.999"] {
            assert!(
                resp.contains(&format!("tor_query_seconds{{verb=\"find\",quantile=\"{q}\"}}")),
                "{resp}"
            );
        }
        assert!(
            resp.contains("tor_query_seconds_count{verb=\"rules\"} 1"),
            "{resp}"
        );
        assert!(resp.contains("tor_uptime_seconds"), "{resp}");
        assert!(resp.contains("tor_active_connections 0"), "{resp}");
    }

    #[test]
    fn metrics_json_variant_is_one_parseable_line() {
        let e = engine();
        e.execute("RULES LIMIT 2");
        let resp = e.execute("METRICS JSON");
        assert_eq!(resp.lines().count(), 1, "{resp}");
        let json = resp.strip_prefix("METRICS JSON ").unwrap();
        let v = crate::util::json::Json::parse(json).unwrap();
        let hist = v
            .get("histograms")
            .unwrap()
            .get("tor_query_seconds{verb=\"rules\"}")
            .unwrap();
        assert_eq!(hist.get("count").unwrap().as_f64(), Some(1.0));
        assert!(hist.get("p99").unwrap().as_f64().unwrap() >= 0.0);
        let counters = v.get("counters").unwrap();
        assert_eq!(
            counters
                .get("tor_queries_total{verb=\"rules\"}")
                .unwrap()
                .as_f64(),
            Some(1.0)
        );
        assert!(e.execute("METRICS bogus").starts_with("ERR usage"));
    }

    #[test]
    fn disabled_metrics_leave_responses_identical() {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        let on = QueryEngine::with_threads(trie.clone(), db.vocab().clone(), 2);
        let off = QueryEngine::with_threads(trie, db.vocab().clone(), 2)
            .with_metrics_enabled(false);
        for cmd in [
            "RULES WHERE conseq = a SORT BY lift DESC LIMIT 5",
            "EXPLAIN ANALYZE RULES WHERE support >= 0.4",
            "FIND f,c => a",
            "TOP confidence 4",
        ] {
            let a = on.execute(cmd);
            let b = off.execute(cmd);
            if cmd.starts_with("EXPLAIN ANALYZE") {
                // Wall times differ run to run; the work counters may not.
                let tokens = |s: &str| {
                    s.split_whitespace()
                        .filter(|t| {
                            t.starts_with("visited=")
                                || t.starts_with("probes=")
                                || t.starts_with("matched=")
                                || t.starts_with("rows=")
                        })
                        .map(str::to_string)
                        .collect::<Vec<_>>()
                };
                assert_eq!(tokens(&a), tokens(&b), "diverged on `{cmd}`");
            } else {
                assert_eq!(a, b, "diverged on `{cmd}`");
            }
        }
        // Stripped mode records nothing.
        let resp = off.execute("STATS");
        assert!(resp.contains(" q_rules=0"), "{resp}");
        assert_eq!(
            on.metrics_registry()
                .counter("tor_queries_total{verb=\"find\"}")
                .get(),
            1
        );
    }

    #[test]
    fn explain_analyze_through_the_service_is_self_delimiting() {
        let e = engine();
        let resp = e.execute("EXPLAIN ANALYZE RULES WHERE conseq = a LIMIT 3");
        let header = resp.lines().next().unwrap();
        let n: usize = header.strip_prefix("EXPLAIN ").unwrap().parse().unwrap();
        assert_eq!(resp.lines().count(), n + 1, "{resp}");
        assert!(resp.contains("conseq-header(a)"), "{resp}");
        assert!(resp.contains("analyze:"), "{resp}");
        assert!(resp.contains("visited="), "{resp}");
        assert!(resp.contains("rows="), "{resp}");
    }

    #[test]
    fn ingest_and_compact_update_registry_gauges() {
        let e = incremental_engine(2);
        e.execute("INGEST f,c,a;b,p");
        let reg = e.metrics_registry();
        assert_eq!(reg.gauge("tor_pending_tx").get(), 2);
        assert_eq!(reg.histogram("tor_ingest_batch_tx").count(), 1);
        e.execute("COMPACT");
        assert_eq!(reg.gauge("tor_pending_tx").get(), 0);
        assert_eq!(reg.gauge("tor_epoch").get(), 1);
        assert_eq!(reg.histogram_seconds("tor_compact_pause_seconds").count(), 1);
    }

    #[test]
    fn stats_reports_build_threads_from_pipeline() {
        let e = engine().with_build_threads(4);
        let resp = e.execute("STATS");
        assert!(resp.contains("build_threads=4"), "{resp}");
    }

    #[test]
    fn engine_thread_degrees_agree_byte_for_byte() {
        // The same request must produce byte-identical responses whatever
        // the engine's degree of parallelism — the service-level face of
        // the executor parity contract.
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        let seq = QueryEngine::with_threads(trie.clone(), db.vocab().clone(), 1);
        let par = QueryEngine::with_threads(trie, db.vocab().clone(), 4);
        assert_eq!(seq.threads(), 1);
        assert_eq!(par.threads(), 4);
        for cmd in [
            "RULES",
            "RULES WHERE conseq = a AND confidence >= 0.6 SORT BY lift DESC LIMIT 5",
            "RULES WHERE support >= 0.6",
            "TOP confidence 4",
            "CONSEQ a",
        ] {
            assert_eq!(seq.execute(cmd), par.execute(cmd), "diverged on `{cmd}`");
        }
        // EXPLAIN through the engine reports the parallel partitioning.
        let resp = par.execute("EXPLAIN RULES");
        assert!(resp.contains("parallel: degree=4"), "{resp}");
    }

    fn incremental_engine(threads: usize) -> QueryEngine {
        use crate::mining::counts::min_count;
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        let vocab = db.vocab().clone();
        let store = crate::trie::delta::IncrementalTrie::new(trie, db, &fi, 0.3).unwrap();
        QueryEngine::with_incremental(store, vocab, ParallelExecutor::new(threads))
    }

    #[test]
    fn ingest_compact_verbs_roundtrip() {
        let e = incremental_engine(2);
        let before = e.execute("RULES");
        let resp = e.execute("INGEST f,c,a;b,p");
        assert!(resp.starts_with("OK ingested=2 pending=2"), "{resp}");
        // The merged view serves immediately: counts (and so the rendered
        // metrics) shift with the cumulative n.
        let during = e.execute("RULES");
        assert_ne!(before, during, "delta did not reach the serving view");
        let stats = e.execute("STATS");
        assert!(stats.contains("pending_tx=2"), "{stats}");
        assert!(stats.contains("epoch=0"), "{stats}");
        // EXPLAIN reports the delta overlay rows.
        let explain = e.execute("EXPLAIN RULES");
        assert!(explain.contains("delta  : epoch 0, 2 pending tx"), "{explain}");
        let resp = e.execute("COMPACT");
        assert!(resp.starts_with("OK compacted epoch=1"), "{resp}");
        // Post-compaction the frozen snapshot serves the same rows the
        // merged view did (batch parity at the compaction boundary).
        let after = e.execute("RULES");
        assert_eq!(during, after, "compaction changed query results");
        let stats = e.execute("STATS");
        assert!(stats.contains("epoch=1"), "{stats}");
        assert!(stats.contains("pending_tx=0"), "{stats}");
        assert!(stats.contains("compactions=1"), "{stats}");
        // Compacting an empty delta is a cheap no-op.
        assert!(e.execute("COMPACT").contains("nothing to compact"));
    }

    #[test]
    fn ingest_auto_compacts_at_threshold() {
        let e = incremental_engine(2).with_compact_threshold(2);
        let resp = e.execute("INGEST f,c");
        assert!(resp.starts_with("OK ingested=1 pending=1"), "{resp}");
        assert!(!resp.contains("compacted"), "{resp}");
        let resp = e.execute("INGEST b,p");
        assert!(resp.contains("compacted"), "{resp}");
        let stats = e.execute("STATS");
        assert!(stats.contains("pending_tx=0"), "{stats}");
        assert!(stats.contains("compactions=1"), "{stats}");
    }

    #[test]
    fn ingest_errors_are_reported() {
        let e = incremental_engine(1);
        assert!(e.execute("INGEST nosuchitem").starts_with("ERR"));
        assert!(e.execute("INGEST").starts_with("ERR usage"));
        // Static engines refuse INGEST/COMPACT outright.
        let s = engine();
        assert!(s.execute("INGEST f,c").starts_with("ERR INGEST requires"));
        assert!(s.execute("COMPACT").starts_with("ERR COMPACT requires"));
    }

    #[test]
    fn ingested_rules_match_a_batch_built_engine() {
        use crate::mining::counts::min_count;
        let e = incremental_engine(4);
        e.execute("INGEST f,c,a,m;f,b;c,b,p");
        // Batch oracle: rebuild from scratch on the cumulative data.
        let db = paper_example_db();
        let mut b = crate::data::transaction::TransactionDb::builder(db.vocab().clone());
        for tx in db.iter() {
            b.push_ids(tx.to_vec());
        }
        let name = |s: &str| db.vocab().get(s).unwrap();
        b.push_ids(vec![name("f"), name("c"), name("a"), name("m")]);
        b.push_ids(vec![name("f"), name("b")]);
        b.push_ids(vec![name("c"), name("b"), name("p")]);
        let cum = b.build();
        let fi = fpgrowth(&cum, 0.3);
        let order = ItemOrder::new(&cum, min_count(0.3, cum.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        let oracle = QueryEngine::with_threads(trie, cum.vocab().clone(), 1);
        for cmd in [
            "RULES",
            "RULES WHERE conseq = a SORT BY lift DESC LIMIT 5",
            "RULES WHERE support >= 0.4",
            "TOP confidence 4",
            "FIND f,c => a",
            "SUPPORT f,c",
        ] {
            assert_eq!(e.execute(cmd), oracle.execute(cmd), "diverged on `{cmd}`");
        }
        // ...and still after compaction.
        e.execute("COMPACT");
        for cmd in ["RULES", "FIND f,c => a", "SUPPORT f,c"] {
            assert_eq!(e.execute(cmd), oracle.execute(cmd), "post-compact `{cmd}`");
        }
    }

    #[test]
    fn snapshot_writes_base_and_delta_sidecar() {
        let dir = std::env::temp_dir().join(format!("tor_snap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("svc.tor");
        let e = incremental_engine(1);
        e.execute("INGEST f,c;b,p");
        let resp = e.execute(&format!("SNAPSHOT {}", path.display()));
        assert!(resp.starts_with("OK snapshot="), "{resp}");
        assert!(resp.contains("pending=2"), "{resp}");
        let (_trie, vocab) = crate::trie::serialize::load(&path).unwrap();
        assert!(vocab.is_some());
        let sidecar = dir.join("svc.tor.delta");
        let (epoch, minsup, txs) = crate::trie::serialize::load_delta(&sidecar).unwrap();
        assert_eq!(epoch, 0);
        assert!((minsup - 0.3).abs() < 1e-12);
        assert_eq!(txs.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tcp_roundtrip() {
        use std::io::{BufRead, BufReader, Write};
        let e = Arc::new(engine());
        let shutdown = Arc::new(AtomicBool::new(false));
        let addr = serve_tcp(Arc::clone(&e), "127.0.0.1:0", Arc::clone(&shutdown)).unwrap();
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"FIND f,c => a\nSTATS\nQUIT\n")
            .unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().map_while(|l| l.ok()).collect();
        assert!(lines[0].starts_with("FOUND"), "{lines:?}");
        assert!(lines[1].starts_with("STATS"), "{lines:?}");
        assert_eq!(lines[2], "BYE");
        shutdown.store(true, Ordering::Relaxed);
    }

    #[test]
    fn tcp_serves_many_sequential_connections() {
        // Exercises the accept loop's handle reaping: every connection
        // fully closes before the next opens, so finished handles pile up
        // unless the loop drains them.
        use std::io::{BufRead, BufReader, Write};
        let e = Arc::new(engine());
        let shutdown = Arc::new(AtomicBool::new(false));
        let addr = serve_tcp(Arc::clone(&e), "127.0.0.1:0", Arc::clone(&shutdown)).unwrap();
        for _ in 0..12 {
            let mut stream = std::net::TcpStream::connect(addr).unwrap();
            stream.write_all(b"STATS\nQUIT\n").unwrap();
            let reader = BufReader::new(stream.try_clone().unwrap());
            let lines: Vec<String> = reader.lines().map_while(|l| l.ok()).collect();
            assert!(lines[0].starts_with("STATS"), "{lines:?}");
            assert_eq!(lines[1], "BYE");
        }
        shutdown.store(true, Ordering::Relaxed);
    }

    #[test]
    fn result_cache_serves_identical_bytes_and_counts() {
        let cached = engine().with_result_cache(4);
        let plain = engine();
        let cmds = [
            "RULES WHERE conseq = a SORT BY lift DESC LIMIT 5",
            "FIND f,c => a",
            "TOP confidence 4",
            "SUPPORT f,c",
            "CONSEQ a",
            "EXPLAIN RULES WHERE conseq = a",
        ];
        for cmd in cmds {
            let first = cached.execute(cmd);
            let second = cached.execute(cmd);
            assert_eq!(first, second, "cache changed bytes for `{cmd}`");
            assert_eq!(first, plain.execute(cmd), "cache diverged on `{cmd}`");
        }
        let reg = cached.metrics_registry();
        assert_eq!(
            reg.counter("tor_result_cache_hits_total").get(),
            cmds.len() as u64
        );
        assert_eq!(
            reg.counter("tor_result_cache_misses_total").get(),
            cmds.len() as u64
        );
        assert_eq!(reg.gauge("tor_result_cache_entries").get(), cmds.len() as i64);
        // Mutating/reporting/ANALYZE verbs bypass the cache entirely.
        cached.execute("STATS");
        cached.execute("STATS");
        cached.execute("EXPLAIN ANALYZE RULES");
        cached.execute("EXPLAIN ANALYZE RULES");
        assert_eq!(
            reg.counter("tor_result_cache_hits_total").get(),
            cmds.len() as u64,
            "non-cacheable verbs must not hit"
        );
        // Errors are recomputed, not cached.
        cached.execute("RULES WHERE bogus >= 1");
        cached.execute("RULES WHERE bogus >= 1");
        assert_eq!(
            reg.counter("tor_result_cache_hits_total").get(),
            cmds.len() as u64
        );
    }

    #[test]
    fn result_cache_invalidates_on_every_view_swap() {
        // The sharp edge this test pins down: INGEST changes query results
        // *without* advancing MergedView::epoch, so a cache keyed on the
        // epoch would serve stale bytes. The generation key must
        // invalidate on both INGEST and COMPACT swaps.
        let cached = incremental_engine(2).with_result_cache(4);
        let plain = incremental_engine(2);
        let probes = ["RULES", "FIND f,c => a", "SUPPORT f,c", "TOP confidence 4"];
        let run_both = |label: &str| {
            for cmd in probes {
                // Twice on the cached engine: the second answer comes from
                // the cache and must still match the uncached engine.
                cached.execute(cmd);
                assert_eq!(
                    cached.execute(cmd),
                    plain.execute(cmd),
                    "stale cache after {label} on `{cmd}`"
                );
            }
        };
        run_both("build");
        cached.execute("INGEST f,c,a,m;f,b");
        plain.execute("INGEST f,c,a,m;f,b");
        run_both("INGEST");
        cached.execute("COMPACT");
        plain.execute("COMPACT");
        run_both("COMPACT");
        let reg = cached.metrics_registry();
        assert!(
            reg.counter("tor_result_cache_invalidations_total").get() >= probes.len() as u64,
            "swaps must invalidate the populated cache"
        );
        assert!(reg.counter("tor_result_cache_hits_total").get() >= probes.len() as u64);
    }

    #[test]
    fn result_cache_accounting_gauges_track_entries() {
        // Byte/entry gauges follow the cache; repeated hits on one key
        // keep exactly one entry and never evict. (LRU eviction itself is
        // pinned down by `query::cache` unit tests.)
        let e = engine().with_result_cache(1);
        for _ in 0..4 {
            e.execute("RULES LIMIT 3");
        }
        let reg = e.metrics_registry();
        assert_eq!(reg.counter("tor_result_cache_evictions_total").get(), 0);
        assert_eq!(reg.gauge("tor_result_cache_entries").get(), 1);
        assert!(reg.gauge("tor_result_cache_bytes").get() > 0);
    }

    #[test]
    fn stats_carries_frontend_and_cache_tail() {
        let e = engine().with_result_cache(2);
        e.execute("RULES LIMIT 1");
        e.execute("RULES LIMIT 1");
        let resp = e.execute("STATS");
        assert!(resp.contains(" shed=0"), "{resp}");
        assert!(resp.contains(" idle_evicted=0"), "{resp}");
        assert!(resp.contains(" cache_hits=1"), "{resp}");
        assert!(resp.contains(" cache_misses=1"), "{resp}");
        assert!(resp.contains(" cache_evictions=0"), "{resp}");
        assert!(resp.contains(" cache_entries=1"), "{resp}");
        // Cache-less engines report zeros, not missing keys (scrapers see
        // a fixed schema).
        let plain = engine();
        let resp = plain.execute("STATS");
        assert!(resp.contains(" cache_hits=0"), "{resp}");
        assert!(resp.contains(" cache_entries=0"), "{resp}");
    }

    #[test]
    fn blocking_server_caps_runaway_lines() {
        use std::io::{BufRead, BufReader, Write};
        let e = Arc::new(engine());
        let shutdown = Arc::new(AtomicBool::new(false));
        let addr =
            serve_tcp_blocking(Arc::clone(&e), "127.0.0.1:0", Arc::clone(&shutdown)).unwrap();
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        // A well-formed command first, so the cap provably doesn't break
        // normal lines…
        stream.write_all(b"SUPPORT f,c\n").unwrap();
        // …then a newline-free flood one byte past the cap (exactly what
        // the capped read consumes: a close with unread client bytes
        // would RST and could clobber the buffered error reply).
        let junk = vec![b'x'; MAX_REQUEST_BYTES + 1];
        stream.write_all(&junk).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().map_while(|l| l.ok()).collect();
        assert_eq!(lines[0], "SUPPORT 3", "{lines:?}");
        assert_eq!(lines[1], "ERR line too long", "{lines:?}");
        assert_eq!(lines.len(), 2, "connection must close after the cap");
        shutdown.store(true, Ordering::Relaxed);
    }
}
