//! Query service: the request loop over a built Trie of Rules.
//!
//! Two frontends share one engine:
//! * an in-process [`QueryEngine`] (used by the CLI and benches), and
//! * a line-protocol TCP server (`tor serve`) — one command per line,
//!   one response per line, so the structure is queryable from anywhere
//!   without Python ever entering the request path.
//!
//! Protocol:
//! ```text
//! FIND a,b => c           -> FOUND sup=.. conf=.. lift=..   | ABSENT | NOTREP
//! TOP <metric> <k>        -> k lines `rule sup conf metric`
//! SUPPORT a,b             -> SUPPORT <count>                | ABSENT
//! CONSEQ c                -> rules with consequent c
//! STATS                   -> node/rule/memory counters
//! QUIT
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::vocab::Vocab;
use crate::rules::metrics::Metric;
use crate::rules::rule::Rule;
use crate::trie::trie::{FindOutcome, TrieOfRules};

/// In-process query engine over a built trie.
pub struct QueryEngine {
    trie: TrieOfRules,
    vocab: Vocab,
    queries: AtomicU64,
}

impl QueryEngine {
    pub fn new(trie: TrieOfRules, vocab: Vocab) -> Self {
        Self {
            trie,
            vocab,
            queries: AtomicU64::new(0),
        }
    }

    pub fn trie(&self) -> &TrieOfRules {
        &self.trie
    }

    pub fn queries_served(&self) -> u64 {
        self.queries.load(Ordering::Relaxed)
    }

    /// Execute one text command, returning the response line(s).
    pub fn execute(&self, line: &str) -> String {
        self.queries.fetch_add(1, Ordering::Relaxed);
        let line = line.trim();
        let (cmd, rest) = line.split_once(' ').unwrap_or((line, ""));
        match cmd.to_ascii_uppercase().as_str() {
            "FIND" => self.cmd_find(rest),
            "TOP" => self.cmd_top(rest),
            "SUPPORT" => self.cmd_support(rest),
            "CONSEQ" => self.cmd_conseq(rest),
            "STATS" => self.cmd_stats(),
            "QUIT" => "BYE".to_string(),
            other => format!("ERR unknown command `{other}`"),
        }
    }

    fn parse_items(&self, s: &str) -> Result<Vec<u32>> {
        s.split(',')
            .map(|name| {
                let name = name.trim();
                self.vocab
                    .get(name)
                    .with_context(|| format!("unknown item `{name}`"))
            })
            .collect()
    }

    fn cmd_find(&self, rest: &str) -> String {
        let Some((a, c)) = rest.split_once("=>") else {
            return "ERR usage: FIND a,b => c".to_string();
        };
        let (a, c) = match (self.parse_items(a), self.parse_items(c)) {
            (Ok(a), Ok(c)) if !a.is_empty() && !c.is_empty() => (a, c),
            (Err(e), _) | (_, Err(e)) => return format!("ERR {e}"),
            _ => return "ERR empty rule side".to_string(),
        };
        if a.iter().any(|i| c.contains(i)) {
            return "ERR overlapping rule sides".to_string();
        }
        match self.trie.find_rule(&Rule::from_ids(a, c)) {
            FindOutcome::Found(m) => format!(
                "FOUND sup={:.6} conf={:.6} lift={:.4} lev={:.6} conv={:.4}",
                m.support, m.confidence, m.lift, m.leverage, m.conviction
            ),
            FindOutcome::NotRepresentable => "NOTREP".to_string(),
            FindOutcome::Absent => "ABSENT".to_string(),
        }
    }

    fn cmd_top(&self, rest: &str) -> String {
        let mut parts = rest.split_whitespace();
        let Some(metric) = parts.next().and_then(Metric::parse) else {
            return "ERR usage: TOP <metric> <k>".to_string();
        };
        let Some(k) = parts.next().and_then(|s| s.parse::<usize>().ok()) else {
            return "ERR usage: TOP <metric> <k>".to_string();
        };
        let top = self.trie.top_n(metric, k);
        let mut out = format!("TOP {} {}\n", metric.name(), top.len());
        for (idx, value) in top {
            let path = self.trie.path_items(idx);
            let (a, c) = path.split_at(path.len() - 1);
            let names = |xs: &[u32]| {
                xs.iter()
                    .map(|&i| self.vocab.name(i))
                    .collect::<Vec<_>>()
                    .join(",")
            };
            out.push_str(&format!(
                "  {{{}}} => {{{}}} {}={:.6}\n",
                names(a),
                names(c),
                metric.name(),
                value
            ));
        }
        out.pop();
        out
    }

    fn cmd_support(&self, rest: &str) -> String {
        match self.parse_items(rest) {
            Ok(items) if !items.is_empty() => match self.trie.support_of(&items) {
                Some(c) => format!("SUPPORT {c}"),
                None => "ABSENT".to_string(),
            },
            Ok(_) => "ERR empty itemset".to_string(),
            Err(e) => format!("ERR {e}"),
        }
    }

    fn cmd_conseq(&self, rest: &str) -> String {
        let Some(item) = self.vocab.get(rest.trim()) else {
            return format!("ERR unknown item `{}`", rest.trim());
        };
        let rules = self.trie.rules_with_consequent(item);
        let mut out = format!("CONSEQ {} {}\n", rest.trim(), rules.len());
        for (idx, m) in rules.iter().take(50) {
            let path = self.trie.path_items(*idx);
            let a = &path[..path.len() - 1];
            let names = a
                .iter()
                .map(|&i| self.vocab.name(i))
                .collect::<Vec<_>>()
                .join(",");
            out.push_str(&format!(
                "  {{{names}}} => {{{}}} conf={:.4}\n",
                rest.trim(),
                m.confidence
            ));
        }
        out.pop();
        out
    }

    fn cmd_stats(&self) -> String {
        format!(
            "STATS nodes={} rules={} mem_kib={} queries={}",
            self.trie.num_nodes(),
            self.trie.num_representable_rules(),
            self.trie.memory_bytes() / 1024,
            self.queries_served()
        )
    }
}

/// Serve the engine over TCP until `shutdown` flips true. Binds `addr`
/// (e.g. `127.0.0.1:7878`); returns the bound address (port 0 supported).
pub fn serve_tcp(
    engine: Arc<QueryEngine>,
    addr: &str,
    shutdown: Arc<AtomicBool>,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    std::thread::spawn(move || {
        let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let engine = Arc::clone(&engine);
                    workers.push(std::thread::spawn(move || {
                        let _ = handle_client(stream, &engine);
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(_) => break,
            }
        }
        for w in workers {
            w.join().ok();
        }
    });
    Ok(local)
}

fn handle_client(stream: TcpStream, engine: &QueryEngine) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let resp = engine.execute(&line);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        if resp == "BYE" {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::paper_example_db;
    use crate::mining::counts::{min_count, ItemOrder};
    use crate::mining::fpgrowth::fpgrowth;

    fn engine() -> QueryEngine {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let order = ItemOrder::new(&db, min_count(0.3, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        QueryEngine::new(trie, db.vocab().clone())
    }

    #[test]
    fn find_command() {
        let e = engine();
        let resp = e.execute("FIND f,c => a");
        assert!(resp.starts_with("FOUND"), "{resp}");
        assert!(resp.contains("conf=1.000000"), "{resp}");
        assert_eq!(e.execute("FIND a => f"), "NOTREP");
        assert_eq!(e.execute("FIND f => d"), "ABSENT");
        assert!(e.execute("FIND f => f").starts_with("ERR"));
        assert!(e.execute("FIND nosuchitem => f").starts_with("ERR"));
        assert!(e.execute("FIND f c").starts_with("ERR usage"));
    }

    #[test]
    fn top_command() {
        let e = engine();
        let resp = e.execute("TOP support 3");
        assert!(resp.starts_with("TOP support 3"), "{resp}");
        assert_eq!(resp.lines().count(), 4);
        assert!(e.execute("TOP bogus 3").starts_with("ERR"));
    }

    #[test]
    fn support_and_conseq_commands() {
        let e = engine();
        assert_eq!(e.execute("SUPPORT f,c"), "SUPPORT 3");
        assert_eq!(e.execute("SUPPORT d"), "ABSENT");
        let resp = e.execute("CONSEQ a");
        assert!(resp.starts_with("CONSEQ a"), "{resp}");
        assert!(resp.lines().count() > 1);
    }

    #[test]
    fn stats_and_counter() {
        let e = engine();
        e.execute("FIND f => c");
        let resp = e.execute("STATS");
        assert!(resp.contains("nodes="), "{resp}");
        assert!(e.queries_served() >= 2);
    }

    #[test]
    fn tcp_roundtrip() {
        use std::io::{BufRead, BufReader, Write};
        let e = Arc::new(engine());
        let shutdown = Arc::new(AtomicBool::new(false));
        let addr = serve_tcp(Arc::clone(&e), "127.0.0.1:0", Arc::clone(&shutdown)).unwrap();
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"FIND f,c => a\nSTATS\nQUIT\n")
            .unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().map_while(|l| l.ok()).collect();
        assert!(lines[0].starts_with("FOUND"), "{lines:?}");
        assert!(lines[1].starts_with("STATS"), "{lines:?}");
        assert_eq!(lines[2], "BYE");
        shutdown.store(true, Ordering::Relaxed);
    }
}
