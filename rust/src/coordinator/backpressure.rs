//! Bounded MPMC queue with blocking backpressure.
//!
//! The offline vendor set has no `tokio`/`crossbeam`, so the streaming
//! pipeline runs on std threads connected by this queue: `push` blocks when
//! the queue is at capacity (producer backpressure), `pop` blocks when it is
//! empty, and `close` drains to `None`. Blocked-time counters feed the
//! pipeline telemetry so backpressure is observable, not silent.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::obs::registry::{Counter, Gauge, MetricsRegistry};

/// Registry handles mirrored by the queue when observability is bound.
struct QueueObs {
    depth: Gauge,
    producer_blocked_ns: Counter,
    consumer_blocked_ns: Counter,
}

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    /// Nanoseconds producers spent blocked on a full queue.
    producer_blocked_ns: AtomicU64,
    /// Nanoseconds consumers spent blocked on an empty queue.
    consumer_blocked_ns: AtomicU64,
    /// Bound once via [`BoundedQueue::bind_metrics`]; `None` keeps the hot
    /// path free of registry traffic.
    obs: OnceLock<QueueObs>,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded blocking queue handle (clone freely; all clones share state).
pub struct BoundedQueue<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for BoundedQueue<T> {
    fn clone(&self) -> Self {
        Self {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "capacity must be positive");
        Self {
            inner: Arc::new(Inner {
                queue: Mutex::new(State {
                    items: VecDeque::with_capacity(capacity),
                    closed: false,
                }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                capacity,
                producer_blocked_ns: AtomicU64::new(0),
                consumer_blocked_ns: AtomicU64::new(0),
                obs: OnceLock::new(),
            }),
        }
    }

    /// Mirror queue depth and blocked time into `registry` under the given
    /// metric prefix (e.g. `tor_pipeline_queue`). Idempotent: later calls
    /// are no-ops, so shared clones can all attempt the bind safely.
    pub fn bind_metrics(&self, registry: &MetricsRegistry, prefix: &str) {
        let _ = self.inner.obs.set(QueueObs {
            depth: registry.gauge(&format!("{prefix}_depth")),
            producer_blocked_ns: registry.counter(&format!("{prefix}_producer_blocked_ns_total")),
            consumer_blocked_ns: registry.counter(&format!("{prefix}_consumer_blocked_ns_total")),
        });
    }

    /// Blocking push. Returns `Err(item)` if the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut state = self.inner.queue.lock().unwrap();
        if state.closed {
            return Err(item);
        }
        if state.items.len() >= self.inner.capacity {
            let start = Instant::now();
            while state.items.len() >= self.inner.capacity && !state.closed {
                state = self.inner.not_full.wait(state).unwrap();
            }
            let blocked = start.elapsed().as_nanos() as u64;
            self.inner
                .producer_blocked_ns
                .fetch_add(blocked, Ordering::Relaxed);
            if let Some(obs) = self.inner.obs.get() {
                obs.producer_blocked_ns.add(blocked);
            }
            if state.closed {
                return Err(item);
            }
        }
        state.items.push_back(item);
        // Publish the gauge while still holding the lock: a set after the
        // drop can race another thread's set and leave a stale depth behind.
        if let Some(obs) = self.inner.obs.get() {
            obs.depth.set(state.items.len() as i64);
        }
        drop(state);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Nonblocking push. Returns `Err(item)` when the queue is closed or at
    /// capacity, so a readiness-loop producer (the service acceptor) can
    /// fall back instead of stalling its event loop.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut state = self.inner.queue.lock().unwrap();
        if state.closed || state.items.len() >= self.inner.capacity {
            return Err(item);
        }
        state.items.push_back(item);
        if let Some(obs) = self.inner.obs.get() {
            obs.depth.set(state.items.len() as i64);
        }
        drop(state);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Nonblocking pop. `None` when the queue is currently empty (closed or
    /// not) — event-loop consumers poll between sweeps rather than parking.
    pub fn try_pop(&self) -> Option<T> {
        let mut state = self.inner.queue.lock().unwrap();
        let item = state.items.pop_front();
        if item.is_some() {
            if let Some(obs) = self.inner.obs.get() {
                obs.depth.set(state.items.len() as i64);
            }
        }
        drop(state);
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Blocking pop. `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut state = self.inner.queue.lock().unwrap();
        if state.items.is_empty() && !state.closed {
            let start = Instant::now();
            while state.items.is_empty() && !state.closed {
                state = self.inner.not_empty.wait(state).unwrap();
            }
            let blocked = start.elapsed().as_nanos() as u64;
            self.inner
                .consumer_blocked_ns
                .fetch_add(blocked, Ordering::Relaxed);
            if let Some(obs) = self.inner.obs.get() {
                obs.consumer_blocked_ns.add(blocked);
            }
        }
        let item = state.items.pop_front();
        if item.is_some() {
            if let Some(obs) = self.inner.obs.get() {
                obs.depth.set(state.items.len() as i64);
            }
        }
        drop(state);
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Close the queue: pending items remain poppable, pushes fail, blocked
    /// threads wake.
    pub fn close(&self) {
        let mut state = self.inner.queue.lock().unwrap();
        state.closed = true;
        drop(state);
        self.inner.not_full.notify_all();
        self.inner.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Cumulative producer/consumer blocked time (backpressure telemetry).
    pub fn blocked_times(&self) -> (Duration, Duration) {
        (
            Duration::from_nanos(self.inner.producer_blocked_ns.load(Ordering::Relaxed)),
            Duration::from_nanos(self.inner.consumer_blocked_ns.load(Ordering::Relaxed)),
        )
    }
}

/// Global admission control for the service front end: a bounded count of
/// in-flight (parsed but not yet answered) requests across every shard.
///
/// Each admitted request holds an [`AdmissionPermit`]; dropping the permit
/// releases the slot. When the bound is hit, [`AdmissionControl::try_acquire`]
/// returns `None` and the caller sheds the request with a `BUSY` response
/// instead of queueing unboundedly — the nonblocking analogue of the thread
/// growth the old per-connection server suffered under overload.
pub struct AdmissionControl {
    inner: Arc<AdmissionInner>,
}

struct AdmissionInner {
    pending: std::sync::atomic::AtomicUsize,
    capacity: usize,
    admitted: AtomicU64,
    shed: AtomicU64,
}

/// RAII admission slot; releases the in-flight count when dropped.
pub struct AdmissionPermit {
    inner: Arc<AdmissionInner>,
}

impl Clone for AdmissionControl {
    fn clone(&self) -> Self {
        Self {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl AdmissionControl {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "admission capacity must be positive");
        Self {
            inner: Arc::new(AdmissionInner {
                pending: std::sync::atomic::AtomicUsize::new(0),
                capacity,
                admitted: AtomicU64::new(0),
                shed: AtomicU64::new(0),
            }),
        }
    }

    /// Claim one in-flight slot, or record a shed and return `None` when the
    /// pending bound is already met.
    pub fn try_acquire(&self) -> Option<AdmissionPermit> {
        let claimed = self
            .inner
            .pending
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |p| {
                if p < self.inner.capacity {
                    Some(p + 1)
                } else {
                    None
                }
            })
            .is_ok();
        if claimed {
            self.inner.admitted.fetch_add(1, Ordering::Relaxed);
            Some(AdmissionPermit {
                inner: Arc::clone(&self.inner),
            })
        } else {
            self.inner.shed.fetch_add(1, Ordering::Relaxed);
            None
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Requests currently holding a permit.
    pub fn pending(&self) -> usize {
        self.inner.pending.load(Ordering::Acquire)
    }

    /// Total requests ever admitted.
    pub fn admitted_count(&self) -> u64 {
        self.inner.admitted.load(Ordering::Relaxed)
    }

    /// Total requests refused (answered `BUSY`).
    pub fn shed_count(&self) -> u64 {
        self.inner.shed.load(Ordering::Relaxed)
    }
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        self.inner.pending.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        q.close();
        let got: Vec<i32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn close_unblocks_consumers() {
        let q: BoundedQueue<i32> = BoundedQueue::new(1);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn push_blocks_at_capacity_and_records_backpressure() {
        let q = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(3));
        thread::sleep(Duration::from_millis(30));
        assert_eq!(q.len(), 2, "third push should be blocked");
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap().unwrap();
        assert_eq!(q.len(), 2);
        let (prod, _) = q.blocked_times();
        assert!(prod >= Duration::from_millis(10), "blocked time {prod:?}");
    }

    #[test]
    fn bound_metrics_mirror_depth_and_blocked_time() {
        let registry = MetricsRegistry::new();
        let q = BoundedQueue::new(2);
        q.bind_metrics(&registry, "tor_test_queue");
        q.bind_metrics(&registry, "tor_test_queue"); // idempotent
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(registry.gauge("tor_test_queue_depth").get(), 2);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(3));
        thread::sleep(Duration::from_millis(30));
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap().unwrap();
        assert!(
            registry
                .counter("tor_test_queue_producer_blocked_ns_total")
                .get()
                > 0,
            "producer blocked time should be mirrored"
        );
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(registry.gauge("tor_test_queue_depth").get(), 0);
    }

    #[test]
    fn push_after_close_fails() {
        let q = BoundedQueue::new(2);
        q.close();
        assert!(q.push(7).is_err());
    }

    #[test]
    fn try_push_and_try_pop_never_block() {
        let q: BoundedQueue<i32> = BoundedQueue::new(2);
        assert_eq!(q.try_pop(), None);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(3), "full queue refuses");
        assert_eq!(q.try_pop(), Some(1));
        q.try_push(3).unwrap();
        q.close();
        assert_eq!(q.try_push(4), Err(4), "closed queue refuses");
        assert_eq!(q.try_pop(), Some(2), "pending items drain after close");
        assert_eq!(q.try_pop(), Some(3));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn admission_caps_pending_and_counts_sheds() {
        let ac = AdmissionControl::new(2);
        let p1 = ac.try_acquire().expect("slot 1");
        let p2 = ac.try_acquire().expect("slot 2");
        assert_eq!(ac.pending(), 2);
        assert!(ac.try_acquire().is_none(), "bound met");
        assert!(ac.try_acquire().is_none());
        assert_eq!(ac.shed_count(), 2);
        drop(p1);
        let p3 = ac.try_acquire().expect("slot freed by drop");
        assert_eq!(ac.pending(), 2);
        drop(p2);
        drop(p3);
        assert_eq!(ac.pending(), 0);
        assert_eq!(ac.admitted_count(), 3);
        assert_eq!(ac.shed_count(), 2);
    }

    #[test]
    fn admission_is_race_free_across_threads() {
        let ac = AdmissionControl::new(8);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let ac = ac.clone();
                thread::spawn(move || {
                    let mut admitted = 0u64;
                    for _ in 0..1000 {
                        if let Some(p) = ac.try_acquire() {
                            admitted += 1;
                            assert!(ac.pending() <= 8, "bound violated");
                            drop(p);
                        }
                    }
                    admitted
                })
            })
            .collect();
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(ac.pending(), 0);
        assert_eq!(ac.admitted_count(), total);
        assert_eq!(ac.admitted_count() + ac.shed_count(), 4000);
    }

    #[test]
    fn depth_gauge_matches_len_after_concurrent_storm() {
        // Regression for the post-unlock gauge publish: two threads could
        // interleave unlock/set and leave a stale depth on the gauge. After a
        // randomized push/pop storm the gauge must equal the true length —
        // not merely converge once the queue quiesces.
        let registry = MetricsRegistry::new();
        let q: BoundedQueue<u64> = BoundedQueue::new(64);
        q.bind_metrics(&registry, "tor_storm_queue");
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let q = q.clone();
                thread::spawn(move || {
                    // xorshift per thread: a deterministic mix of try_push /
                    // try_pop with no coordination between threads.
                    let mut s = 0x9E37_79B9u64.wrapping_add(t as u64);
                    for i in 0..5000u64 {
                        s ^= s << 13;
                        s ^= s >> 7;
                        s ^= s << 17;
                        if s & 1 == 0 {
                            let _ = q.try_push(t as u64 * 10_000 + i);
                        } else {
                            let _ = q.try_pop();
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let depth = registry.gauge("tor_storm_queue_depth").get();
        assert_eq!(
            depth as usize,
            q.len(),
            "gauge drifted from true depth after storm"
        );
    }

    #[test]
    fn mpmc_many_producers_consumers() {
        let q: BoundedQueue<u64> = BoundedQueue::new(8);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..250u64 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(x) = q.pop() {
                        got.push(x);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all.len(), 1000);
        all.dedup();
        assert_eq!(all.len(), 1000, "duplicate or lost items");
    }
}
