//! The durability plane (DESIGN.md §16): crash-safe state for the
//! incremental serving path.
//!
//! One directory (`--wal-dir`) holds everything needed to restart with
//! zero acknowledged-INGEST loss:
//!
//! ```text
//! wal-dir/
//!   MANIFEST       tiny sealed pointer: newest valid checkpoint + wal seq
//!   wal.log        write-ahead log of INGEST/COMPACT since that checkpoint
//!   ckpt-<id>.tor  v4 snapshot of the base trie (with vocab, CRC-sealed,
//!                  mmap-servable; v3-era checkpoints still recover)
//!   ckpt-<id>.db   sealed dump of the base transaction database
//! ```
//!
//! Protocol invariants:
//! - WAL append (under the configured fsync policy) happens **before**
//!   the mutation is applied or acknowledged; replay order equals apply
//!   order because both happen under the store lock.
//! - Checkpoints are written temp + fsync + atomic rename, **then** the
//!   manifest is atomically swapped, **then** the WAL is truncated — so
//!   the manifest always points at a complete, CRC-valid checkpoint and a
//!   crash anywhere leaves a recoverable pair.
//! - Recovery = load manifest checkpoint, rebuild the incremental store
//!   (the closed frequent set is recovered 1:1 from the trie's nodes),
//!   replay WAL records with `seq > manifest.wal_seq`, then immediately
//!   re-checkpoint and start a fresh log — recovery is idempotent and
//!   the log never grows across restarts.
//! - Any WAL/checkpoint write failure flips the plane to **degraded**
//!   (read-only) mode instead of panicking: queries keep serving, INGEST
//!   and COMPACT are refused with `ERR degraded`, and STATS/metrics
//!   expose the condition.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::wal::{read_wal, FsyncPolicy, Wal, WalOp};
use crate::data::vocab::Vocab;
use crate::mining::itemset::{FrequentItemsets, Itemset};
use crate::trie::delta::IncrementalTrie;
use crate::trie::serialize;
use crate::trie::trie::TrieOfRules;
use crate::util::crc32::crc32;
use crate::util::fsio::{self, Vfs};

const MANIFEST_MAGIC: [u8; 4] = *b"TORM";
const MANIFEST_VERSION: u32 = 1;

/// The sealed recovery pointer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Manifest {
    /// Checkpoint file id this manifest points at (`ckpt-<id>.*`).
    pub checkpoint_id: u64,
    /// Store epoch at checkpoint time.
    pub epoch: u64,
    /// Store compaction count at checkpoint time.
    pub compactions: u64,
    /// Support threshold the store was created with (bit-exact).
    pub minsup: f64,
    /// Highest WAL sequence number the checkpoint supersedes; recovery
    /// replays only records with `seq > wal_seq`.
    pub wal_seq: u64,
}

impl Manifest {
    fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(52);
        b.extend_from_slice(&MANIFEST_MAGIC);
        b.extend_from_slice(&MANIFEST_VERSION.to_le_bytes());
        b.extend_from_slice(&self.checkpoint_id.to_le_bytes());
        b.extend_from_slice(&self.epoch.to_le_bytes());
        b.extend_from_slice(&self.compactions.to_le_bytes());
        b.extend_from_slice(&self.minsup.to_bits().to_le_bytes());
        b.extend_from_slice(&self.wal_seq.to_le_bytes());
        let crc = crc32(&b);
        b.extend_from_slice(&crc.to_le_bytes());
        b
    }

    fn decode(bytes: &[u8]) -> Result<Manifest> {
        anyhow::ensure!(bytes.len() == 52, "manifest wrong size {}", bytes.len());
        anyhow::ensure!(bytes[..4] == MANIFEST_MAGIC, "manifest bad magic");
        let stored = u32::from_le_bytes(bytes[48..52].try_into().unwrap());
        anyhow::ensure!(stored == crc32(&bytes[..48]), "manifest checksum mismatch");
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        anyhow::ensure!(version == MANIFEST_VERSION, "manifest version {version}");
        let u = |a: usize| u64::from_le_bytes(bytes[a..a + 8].try_into().unwrap());
        Ok(Manifest {
            checkpoint_id: u(8),
            epoch: u(16),
            compactions: u(24),
            minsup: f64::from_bits(u(32)),
            wal_seq: u(40),
        })
    }

    fn save(&self, vfs: &dyn Vfs, path: &Path) -> Result<()> {
        let bytes = self.encode();
        fsio::atomic_write_with(vfs, path, |w| w.write_all(&bytes))
            .with_context(|| format!("save manifest {}", path.display()))
    }

    fn load(vfs: &dyn Vfs, path: &Path) -> Result<Manifest> {
        let bytes = vfs
            .read(path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        Self::decode(&bytes)
    }
}

/// What recovery did at startup.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// True when no manifest existed and the base was built fresh.
    pub cold_start: bool,
    /// Checkpoint id loaded (recovery) or written (cold start).
    pub checkpoint_id: u64,
    /// INGEST records replayed from the WAL tail.
    pub replayed_ingests: usize,
    /// COMPACT records replayed from the WAL tail.
    pub replayed_compacts: usize,
    /// Transactions carried by the replayed INGEST records.
    pub replayed_tx: usize,
}

/// Shared, thread-safe handle the service uses to make mutations durable.
pub struct DurabilityPlane {
    vfs: Arc<dyn Vfs>,
    dir: PathBuf,
    policy: FsyncPolicy,
    vocab: Vocab,
    wal: Mutex<Wal>,
    manifest: Mutex<Manifest>,
    degraded: AtomicBool,
    last_error: Mutex<Option<String>>,
    wal_appends: AtomicU64,
    checkpoints: AtomicU64,
}

impl DurabilityPlane {
    /// Open (or initialize) a durability directory and return the plane
    /// plus the recovered incremental store. `build_base` runs the full
    /// mining pipeline and is only invoked on cold start — a warm start
    /// restores from the checkpoint + WAL without re-mining.
    pub fn open_or_recover<F>(
        vfs: Arc<dyn Vfs>,
        dir: &Path,
        policy: FsyncPolicy,
        build_base: F,
    ) -> Result<(DurabilityPlane, IncrementalTrie, Vocab, RecoveryReport)>
    where
        F: FnOnce() -> Result<(IncrementalTrie, Vocab)>,
    {
        vfs.create_dir_all(dir)
            .with_context(|| format!("create wal dir {}", dir.display()))?;
        let manifest_path = dir.join("MANIFEST");
        let wal_path = dir.join("wal.log");
        if vfs.exists(&manifest_path) {
            Self::recover(vfs, dir, policy, &manifest_path, &wal_path)
        } else {
            Self::cold_start(vfs, dir, policy, &manifest_path, &wal_path, build_base)
        }
    }

    fn cold_start<F>(
        vfs: Arc<dyn Vfs>,
        dir: &Path,
        policy: FsyncPolicy,
        manifest_path: &Path,
        wal_path: &Path,
        build_base: F,
    ) -> Result<(DurabilityPlane, IncrementalTrie, Vocab, RecoveryReport)>
    where
        F: FnOnce() -> Result<(IncrementalTrie, Vocab)>,
    {
        let (store, vocab) = build_base().context("build base for durability cold start")?;
        anyhow::ensure!(
            store.pending_len() == 0,
            "durability cold start requires a compacted base (pending = {})",
            store.pending_len()
        );
        let manifest = Manifest {
            checkpoint_id: 0,
            epoch: store.epoch(),
            compactions: store.compactions(),
            minsup: store.minsup(),
            wal_seq: 0,
        };
        write_checkpoint(vfs.as_ref(), dir, manifest.checkpoint_id, &store, &vocab)?;
        manifest.save(vfs.as_ref(), manifest_path)?;
        let wal = Wal::create(Arc::clone(&vfs), wal_path, policy, 1)?;
        let report = RecoveryReport {
            cold_start: true,
            checkpoint_id: 0,
            ..Default::default()
        };
        let plane = DurabilityPlane {
            vfs,
            dir: dir.to_path_buf(),
            policy,
            vocab: vocab.clone(),
            wal: Mutex::new(wal),
            manifest: Mutex::new(manifest),
            degraded: AtomicBool::new(false),
            last_error: Mutex::new(None),
            wal_appends: AtomicU64::new(0),
            checkpoints: AtomicU64::new(1),
        };
        Ok((plane, store, vocab, report))
    }

    fn recover(
        vfs: Arc<dyn Vfs>,
        dir: &Path,
        policy: FsyncPolicy,
        manifest_path: &Path,
        wal_path: &Path,
    ) -> Result<(DurabilityPlane, IncrementalTrie, Vocab, RecoveryReport)> {
        let manifest = Manifest::load(vfs.as_ref(), manifest_path)?;
        // v4 checkpoints are served straight from the mapping. Trusted
        // mode: this plane wrote the file itself (save_with + fsync +
        // atomic rename) and the manifest names it — only the header
        // seals are re-verified, so recovery cost is O(WAL replay), not
        // O(snapshot bytes). Files from outside this trust boundary go
        // through `serialize::open` / `load`, which verify everything.
        // Pre-v4 checkpoints fall back to the owned loader inside.
        let (trie, vocab) = serialize::open_with_mode(
            vfs.as_ref(),
            &checkpoint_trie_path(dir, manifest.checkpoint_id),
            serialize::OpenMode::Trusted,
        )
        .map_err(|e| anyhow::anyhow!("open checkpoint {}: {e}", manifest.checkpoint_id))?;
        let vocab =
            vocab.ok_or_else(|| anyhow::anyhow!("checkpoint snapshot is missing its vocab"))?;
        let db = serialize::load_db_with(
            vfs.as_ref(),
            &checkpoint_db_path(dir, manifest.checkpoint_id),
        )
        .map_err(|e| anyhow::anyhow!("load checkpoint db {}: {e}", manifest.checkpoint_id))?;
        let frequent = frequent_from_trie(&trie);
        let mut store = IncrementalTrie::restore(
            trie,
            db,
            &frequent,
            manifest.minsup,
            manifest.epoch,
            manifest.compactions,
        )
        .context("rebuild incremental store from checkpoint")?;

        // Replay the WAL tail. A missing log (crash after the manifest
        // swap, before the fresh log materialized) means an empty tail.
        // `cut` tracks the highest sequence number a re-checkpoint of the
        // base would supersede: the last replayed COMPACT barrier.
        // Records after it feed `pending` and must stay in the log.
        let mut report = RecoveryReport {
            cold_start: false,
            checkpoint_id: manifest.checkpoint_id,
            ..Default::default()
        };
        let mut last_seq = manifest.wal_seq;
        let mut cut = manifest.wal_seq;
        let mut records = Vec::new();
        if vfs.exists(wal_path) {
            let (start_seq, recs) = read_wal(vfs.as_ref(), wal_path)?;
            records = recs;
            last_seq = last_seq.max(start_seq.saturating_sub(1));
            for rec in &records {
                last_seq = last_seq.max(rec.seq);
                if rec.seq <= manifest.wal_seq {
                    continue; // superseded by the checkpoint
                }
                match &rec.op {
                    WalOp::Ingest(txs) => {
                        report.replayed_ingests += 1;
                        report.replayed_tx += txs.len();
                        store.ingest(txs).context("replay wal ingest")?;
                    }
                    WalOp::Compact => {
                        report.replayed_compacts += 1;
                        cut = rec.seq;
                        store.compact(None).context("replay wal compact")?;
                    }
                }
            }
        }

        // Recovery logs no new records — the atomic manifest rename is
        // the single commit point, and until it lands the old (manifest,
        // checkpoint, wal) triple stays byte-for-byte intact. When replay
        // advanced the base (a COMPACT was replayed), fold it into a
        // fresh checkpoint so the next start replays less; pending ingest
        // records (seq > cut) stay covered by the log rewrite below.
        let mut manifest = manifest;
        if report.replayed_compacts > 0 {
            let new_manifest = Manifest {
                checkpoint_id: manifest.checkpoint_id + 1,
                epoch: store.epoch(),
                compactions: store.compactions(),
                minsup: manifest.minsup,
                wal_seq: cut,
            };
            write_checkpoint(vfs.as_ref(), dir, new_manifest.checkpoint_id, &store, &vocab)?;
            new_manifest.save(vfs.as_ref(), manifest_path)?;
            remove_checkpoint(vfs.as_ref(), dir, manifest.checkpoint_id);
            manifest = new_manifest;
        }
        // Truncating is only safe once nothing after the manifest's
        // `wal_seq` is still needed. When pending records remain, the
        // survived file cannot simply be reopened for append: the crash
        // may have left a torn partial frame beyond the last whole record
        // and the reader stops there — shadowing anything appended after
        // recovery. Atomically rewrite the log to exactly the still-needed
        // tail instead (a crash mid-rewrite keeps the old complete log).
        let wal = if store.pending_len() == 0 {
            Wal::create(Arc::clone(&vfs), wal_path, policy, last_seq + 1)?
        } else {
            records.retain(|r| r.seq > cut);
            Wal::rewrite(Arc::clone(&vfs), wal_path, policy, cut + 1, &records)?
        };
        report.checkpoint_id = manifest.checkpoint_id;

        let plane = DurabilityPlane {
            vfs,
            dir: dir.to_path_buf(),
            policy,
            vocab: vocab.clone(),
            wal: Mutex::new(wal),
            manifest: Mutex::new(manifest),
            degraded: AtomicBool::new(false),
            last_error: Mutex::new(None),
            wal_appends: AtomicU64::new(0),
            checkpoints: AtomicU64::new(u64::from(report.replayed_compacts > 0)),
        };
        Ok((plane, store, vocab, report))
    }

    pub fn policy(&self) -> FsyncPolicy {
        self.policy
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Acquire)
    }

    /// Why the plane degraded (if it did).
    pub fn last_error(&self) -> Option<String> {
        self.last_error.lock().unwrap().clone()
    }

    /// Records appended since startup.
    pub fn wal_appends(&self) -> u64 {
        self.wal_appends.load(Ordering::Relaxed)
    }

    /// Checkpoints written since startup (includes the startup one).
    pub fn checkpoints_written(&self) -> u64 {
        self.checkpoints.load(Ordering::Relaxed)
    }

    /// Sequence number the next WAL append will use.
    pub fn next_seq(&self) -> u64 {
        self.wal.lock().unwrap().next_seq()
    }

    fn degrade(&self, what: &str, err: &anyhow::Error) {
        self.degraded.store(true, Ordering::Release);
        let mut g = self.last_error.lock().unwrap();
        *g = Some(format!("{what}: {err:#}"));
    }

    /// Make an INGEST batch durable *before* it is applied/acknowledged.
    /// `epoch` is the store epoch at append time. On failure the plane
    /// flips to degraded mode and the caller must refuse the mutation.
    pub fn log_ingest(&self, epoch: u64, txs: &[Vec<u32>]) -> Result<u64> {
        anyhow::ensure!(!self.is_degraded(), "durability plane is degraded");
        let mut wal = self.wal.lock().unwrap();
        match wal.append(epoch, &WalOp::Ingest(txs.to_vec())) {
            Ok(seq) => {
                self.wal_appends.fetch_add(1, Ordering::Relaxed);
                Ok(seq)
            }
            Err(e) => {
                self.degrade("wal append", &e);
                Err(e)
            }
        }
    }

    /// Record a completed COMPACT: append the barrier record, force the
    /// log down, write checkpoint `id+1` from the (already-compacted)
    /// store, swap the manifest, truncate the log. Call with the store
    /// lock held, *after* `compact()` succeeded.
    pub fn log_compact_and_checkpoint(&self, store: &IncrementalTrie) -> Result<()> {
        anyhow::ensure!(!self.is_degraded(), "durability plane is degraded");
        let result = self.checkpoint_inner(store);
        if let Err(e) = &result {
            self.degrade("checkpoint", e);
        }
        result
    }

    fn checkpoint_inner(&self, store: &IncrementalTrie) -> Result<()> {
        let mut wal = self.wal.lock().unwrap();
        wal.append(store.epoch(), &WalOp::Compact)?;
        self.wal_appends.fetch_add(1, Ordering::Relaxed);
        wal.sync()?;
        let superseded = wal.next_seq() - 1;
        let mut manifest = self.manifest.lock().unwrap();
        let new_manifest = Manifest {
            checkpoint_id: manifest.checkpoint_id + 1,
            epoch: store.epoch(),
            compactions: store.compactions(),
            minsup: manifest.minsup,
            wal_seq: superseded,
        };
        write_checkpoint(
            self.vfs.as_ref(),
            &self.dir,
            new_manifest.checkpoint_id,
            store,
            &self.vocab,
        )?;
        new_manifest.save(self.vfs.as_ref(), &self.dir.join("MANIFEST"))?;
        wal.truncate()?;
        let old_id = manifest.checkpoint_id;
        *manifest = new_manifest;
        drop(manifest);
        drop(wal);
        remove_checkpoint(self.vfs.as_ref(), &self.dir, old_id);
        self.checkpoints.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Shutdown drain: force the log durable regardless of fsync policy.
    pub fn shutdown_flush(&self) -> Result<()> {
        if self.is_degraded() {
            return Ok(()); // nothing trustworthy to flush
        }
        let mut wal = self.wal.lock().unwrap();
        if let Err(e) = wal.sync() {
            self.degrade("shutdown fsync", &e);
            return Err(e);
        }
        Ok(())
    }

    /// The STATS tail this plane contributes (appended only when a plane
    /// is attached, keeping WAL-less serving byte-identical to before).
    pub fn stats_fields(&self) -> String {
        format!(
            " wal_fsync={} wal_seq={} wal_appends={} checkpoints={} degraded={}",
            self.policy,
            self.next_seq(),
            self.wal_appends(),
            self.checkpoints_written(),
            u8::from(self.is_degraded()),
        )
    }
}

fn checkpoint_trie_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("ckpt-{id}.tor"))
}

fn checkpoint_db_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("ckpt-{id}.db"))
}

fn write_checkpoint(
    vfs: &dyn Vfs,
    dir: &Path,
    id: u64,
    store: &IncrementalTrie,
    vocab: &Vocab,
) -> Result<()> {
    serialize::save_with(vfs, store.base(), Some(vocab), &checkpoint_trie_path(dir, id))?;
    serialize::save_db_with(vfs, store.base_db(), &checkpoint_db_path(dir, id))?;
    Ok(())
}

fn remove_checkpoint(vfs: &dyn Vfs, dir: &Path, id: u64) {
    // Best-effort GC of the superseded checkpoint pair.
    let _ = vfs.remove(&checkpoint_trie_path(dir, id));
    let _ = vfs.remove(&checkpoint_db_path(dir, id));
}

/// Recover the complete (subset-closed) frequent-itemset collection from
/// a frozen trie: each non-root node is exactly one frequent itemset
/// (its root path) with its support count — the 1:1 correspondence the
/// paper's construction gives and `IncrementalTrie` validates.
pub fn frequent_from_trie(trie: &TrieOfRules) -> FrequentItemsets {
    let counts = trie.counts_column();
    let mut sets = Vec::with_capacity(trie.num_nodes());
    for idx in 1..=trie.num_nodes() {
        let items = trie.path_items(idx as u32);
        sets.push((Itemset::new(items), counts[idx]));
    }
    let mut fi = FrequentItemsets {
        num_transactions: trie.num_transactions(),
        sets,
    };
    fi.canonicalize();
    fi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::{paper_example_db, TransactionDb};
    use crate::data::vocab::ItemId;
    use crate::mining::counts::{min_count, ItemOrder};
    use crate::mining::fpgrowth::fpgrowth;
    use crate::util::fsio::MemVfs;

    const MINSUP: f64 = 0.3;

    fn build_paper_base() -> Result<(IncrementalTrie, Vocab)> {
        let db = paper_example_db();
        let fi = fpgrowth(&db, MINSUP);
        let order = ItemOrder::new(&db, min_count(MINSUP, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order)?;
        let vocab = db.vocab().clone();
        let store = IncrementalTrie::new(trie, db, &fi, MINSUP)?;
        Ok((store, vocab))
    }

    fn batch_trie(rows: &[Vec<ItemId>], vocab: &Vocab) -> TrieOfRules {
        let mut b = TransactionDb::builder(vocab.clone());
        for r in rows {
            b.push_ids(r.clone());
        }
        let db = b.build();
        let fi = fpgrowth(&db, MINSUP);
        let order = ItemOrder::new(&db, min_count(MINSUP, db.num_transactions()));
        TrieOfRules::from_sorted_paths(&fi, &order).unwrap()
    }

    fn base_bytes(store: &IncrementalTrie, vocab: &Vocab) -> Vec<u8> {
        let mut out = Vec::new();
        serialize::save_to(store.base(), Some(vocab), &mut out).unwrap();
        out
    }

    fn open(
        vfs: &MemVfs,
        dir: &Path,
    ) -> Result<(DurabilityPlane, IncrementalTrie, Vocab, RecoveryReport)> {
        let arc: Arc<dyn Vfs> = Arc::new(vfs.clone());
        DurabilityPlane::open_or_recover(arc, dir, FsyncPolicy::Always, build_paper_base)
    }

    #[test]
    fn manifest_roundtrip_and_corruption_rejection() {
        let m = Manifest {
            checkpoint_id: 7,
            epoch: 3,
            compactions: 2,
            minsup: 0.3,
            wal_seq: 41,
        };
        let bytes = m.encode();
        assert_eq!(Manifest::decode(&bytes).unwrap(), m);
        assert!(Manifest::decode(&bytes[..51]).is_err());
        for byte in 0..bytes.len() {
            let mut b = bytes.clone();
            b[byte] ^= 0x04;
            assert!(Manifest::decode(&b).is_err(), "flip at {byte} accepted");
        }
    }

    #[test]
    fn cold_start_lays_down_checkpoint_manifest_and_wal() {
        let vfs = MemVfs::new(11);
        let dir = Path::new("wal");
        let (plane, store, _vocab, report) = open(&vfs, dir).unwrap();
        assert!(report.cold_start);
        assert_eq!(report.checkpoint_id, 0);
        assert!(vfs.exists(&dir.join("MANIFEST")));
        assert!(vfs.exists(&dir.join("wal.log")));
        assert!(vfs.exists(&dir.join("ckpt-0.tor")));
        assert!(vfs.exists(&dir.join("ckpt-0.db")));
        assert_eq!(plane.next_seq(), 1);
        assert_eq!(store.pending_len(), 0);
        assert!(!plane.is_degraded());
    }

    #[test]
    fn recovery_replays_acknowledged_ingests() {
        let vfs = MemVfs::new(12);
        let dir = Path::new("wal");
        let (plane, mut store, _vocab, _) = open(&vfs, dir).unwrap();
        let batch = vec![vec![0u32, 1, 2], vec![3, 4]];
        plane.log_ingest(store.epoch(), &batch).unwrap();
        store.ingest(&batch).unwrap();
        drop(plane);

        let (plane2, store2, _vocab2, report) = DurabilityPlane::open_or_recover(
            Arc::new(vfs.clone()) as Arc<dyn Vfs>,
            dir,
            FsyncPolicy::Always,
            || anyhow::bail!("warm start must not rebuild the base"),
        )
        .unwrap();
        assert!(!report.cold_start);
        assert_eq!(report.replayed_ingests, 1);
        assert_eq!(report.replayed_tx, 2);
        assert_eq!(store2.pending_len(), 2);
        assert_eq!(store2.pending(), store.pending());
        assert_eq!(store2.epoch(), store.epoch());
        // The pending tail must survive a second crash too: the log still
        // covers it (recovery does not truncate past pending records).
        drop(plane2);
        let (_, store3, _, report3) = DurabilityPlane::open_or_recover(
            Arc::new(vfs.clone()) as Arc<dyn Vfs>,
            dir,
            FsyncPolicy::Always,
            || anyhow::bail!("warm start must not rebuild the base"),
        )
        .unwrap();
        assert_eq!(report3.replayed_ingests, 1);
        assert_eq!(store3.pending(), store.pending());
    }

    #[test]
    fn compact_checkpoint_truncates_and_recovery_matches_batch_rebuild() {
        let vfs = MemVfs::new(13);
        let dir = Path::new("wal");
        let (plane, mut store, vocab, _) = open(&vfs, dir).unwrap();
        let db = paper_example_db();
        let name = |s: &str| db.vocab().get(s).unwrap();
        let batch = vec![
            vec![name("f"), name("c"), name("a")],
            vec![name("b"), name("p")],
        ];
        plane.log_ingest(store.epoch(), &batch).unwrap();
        store.ingest(&batch).unwrap();
        assert!(store.compact(None).unwrap());
        plane.log_compact_and_checkpoint(&store).unwrap();
        assert!(vfs.exists(&dir.join("ckpt-1.tor")));
        assert!(!vfs.exists(&dir.join("ckpt-0.tor")), "old ckpt not GC'd");
        drop(plane);

        let (_, store2, vocab2, report) = DurabilityPlane::open_or_recover(
            Arc::new(vfs.clone()) as Arc<dyn Vfs>,
            dir,
            FsyncPolicy::Always,
            || anyhow::bail!("warm start must not rebuild the base"),
        )
        .unwrap();
        assert_eq!(report.replayed_ingests, 0, "checkpoint superseded the log");
        assert_eq!(store2.compactions(), 1);
        assert_eq!(store2.pending_len(), 0);
        let mut rows: Vec<Vec<ItemId>> = db.iter().map(|t| t.to_vec()).collect();
        rows.extend(batch);
        let batch_rebuild = batch_trie(&rows, &vocab);
        let mut want = Vec::new();
        serialize::save_to(&batch_rebuild, Some(&vocab), &mut want).unwrap();
        assert_eq!(
            base_bytes(&store2, &vocab2),
            want,
            "recovered snapshot differs from batch rebuild"
        );
    }

    #[test]
    fn recovery_replays_a_compact_record_without_its_checkpoint() {
        // Crash after the COMPACT record hit the log but before the
        // checkpoint/manifest swap: replay must redo the compaction.
        let vfs = MemVfs::new(14);
        let dir = Path::new("wal");
        let (plane, mut store, vocab, _) = open(&vfs, dir).unwrap();
        let batch = vec![vec![0u32, 1], vec![2u32]];
        plane.log_ingest(store.epoch(), &batch).unwrap();
        store.ingest(&batch).unwrap();
        store.compact(None).unwrap();
        let expect = base_bytes(&store, &vocab);
        // Simulate the crash window by appending the barrier record
        // directly, skipping checkpoint + manifest + truncation.
        {
            let mut wal = plane.wal.lock().unwrap();
            wal.append(store.epoch(), &WalOp::Compact).unwrap();
            wal.sync().unwrap();
        }
        drop(plane);

        let (_, store2, vocab2, report) = DurabilityPlane::open_or_recover(
            Arc::new(vfs.clone()) as Arc<dyn Vfs>,
            dir,
            FsyncPolicy::Always,
            || anyhow::bail!("warm start must not rebuild the base"),
        )
        .unwrap();
        assert_eq!(report.replayed_ingests, 1);
        assert_eq!(report.replayed_compacts, 1);
        assert_eq!(store2.compactions(), 1);
        assert_eq!(store2.pending_len(), 0);
        assert_eq!(base_bytes(&store2, &vocab2), expect);
        // Replayed compaction was folded into a fresh checkpoint.
        assert!(vfs.exists(&dir.join("ckpt-1.tor")));
        assert!(!vfs.exists(&dir.join("ckpt-0.tor")));
    }

    #[test]
    fn wal_failure_degrades_instead_of_panicking() {
        let vfs = MemVfs::new(15);
        let dir = Path::new("wal");
        let (plane, store, _, _) = open(&vfs, dir).unwrap();
        vfs.fail_path_containing(Some("wal.log"));
        let err = plane.log_ingest(store.epoch(), &[vec![1u32]]).unwrap_err();
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        assert!(plane.is_degraded());
        assert!(plane.last_error().unwrap().contains("wal append"));
        // Every further mutation is refused without touching the log.
        vfs.fail_path_containing(None);
        let err = plane.log_ingest(store.epoch(), &[vec![2u32]]).unwrap_err();
        assert!(format!("{err}").contains("degraded"));
        assert!(plane.log_compact_and_checkpoint(&store).is_err());
        assert!(plane.stats_fields().contains("degraded=1"));
    }

    #[test]
    fn frequent_from_trie_matches_the_miner() {
        let db = paper_example_db();
        let mut fi = fpgrowth(&db, MINSUP);
        fi.canonicalize();
        let order = ItemOrder::new(&db, min_count(MINSUP, db.num_transactions()));
        let trie = TrieOfRules::from_frequent(&fi, &order).unwrap();
        let derived = frequent_from_trie(&trie);
        assert_eq!(derived.num_transactions, fi.num_transactions);
        assert_eq!(derived.sets, fi.sets);
    }

    #[test]
    fn stats_fields_report_policy_and_progress() {
        let vfs = MemVfs::new(16);
        let (plane, store, _, _) = open(&vfs, Path::new("wal")).unwrap();
        plane.log_ingest(store.epoch(), &[vec![1u32, 2]]).unwrap();
        let s = plane.stats_fields();
        assert!(s.contains("wal_fsync=always"), "{s}");
        assert!(s.contains("wal_appends=1"), "{s}");
        assert!(s.contains("degraded=0"), "{s}");
    }
}
