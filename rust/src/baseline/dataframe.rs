//! The dataframe baseline — a rust port of the *algorithmic semantics* of
//! the pandas ruleset representation the paper compares against
//! (DESIGN.md §5.3).
//!
//! Like `mlxtend`/`arulespy`, the ruleset is a flat columnar table: one row
//! per rule, columns for antecedent, consequent, and each metric. The three
//! evaluated operations deliberately mirror pandas:
//!
//! * `find` — a **full boolean mask scan** over all rows
//!   (`df[(df.antecedents == a) & (df.consequents == c)]`): no early exit,
//!   no index.
//! * `top_n` — a **full stable sort** of row indices by the metric column,
//!   then head(k) (`df.sort_values(...).head(k)`).
//! * `for_each_row` — row-wise traversal through the column stores.
//!
//! As the RQL parity oracle (DESIGN.md §5.3/§7) the frame's *row order* is
//! whatever ruleset order it was built from — since the freeze refactor
//! that is the frozen trie's preorder enumeration when built off
//! `collect_rules()`. Parity never depends on it: both query backends
//! normalize rows through the same `(sort key, rule)` total order before
//! emission, and top-N comparisons assert on metric values.

use crate::rules::metrics::{Metric, RuleMetrics};
use crate::rules::rule::Rule;
use crate::rules::ruleset::{RuleSet, ScoredRule};

/// Columnar rule table with pandas-faithful operation semantics.
#[derive(Debug, Clone, Default)]
pub struct RuleFrame {
    antecedents: Vec<Box<[u32]>>,
    consequents: Vec<Box<[u32]>>,
    support: Vec<f64>,
    confidence: Vec<f64>,
    lift: Vec<f64>,
    leverage: Vec<f64>,
    conviction: Vec<f64>,
    zhang: Vec<f64>,
    jaccard: Vec<f64>,
    cosine: Vec<f64>,
    kulczynski: Vec<f64>,
    yule_q: Vec<f64>,
}

impl RuleFrame {
    /// Build from a mined ruleset.
    pub fn from_ruleset(rs: &RuleSet) -> Self {
        Self::from_scored(rs.rules())
    }

    /// Build from scored rules (also used for trie-parity fixtures).
    pub fn from_scored(rules: &[ScoredRule]) -> Self {
        let mut f = RuleFrame::default();
        for sr in rules {
            f.push(&sr.rule, &sr.metrics);
        }
        f
    }

    /// Append one row.
    pub fn push(&mut self, rule: &Rule, m: &RuleMetrics) {
        self.antecedents
            .push(rule.antecedent.items().to_vec().into_boxed_slice());
        self.consequents
            .push(rule.consequent.items().to_vec().into_boxed_slice());
        self.support.push(m.support);
        self.confidence.push(m.confidence);
        self.lift.push(m.lift);
        self.leverage.push(m.leverage);
        self.conviction.push(m.conviction);
        self.zhang.push(m.zhang);
        self.jaccard.push(m.jaccard);
        self.cosine.push(m.cosine);
        self.kulczynski.push(m.kulczynski);
        self.yule_q.push(m.yule_q);
    }

    pub fn len(&self) -> usize {
        self.support.len()
    }

    pub fn is_empty(&self) -> bool {
        self.support.is_empty()
    }

    fn column(&self, metric: Metric) -> &[f64] {
        match metric {
            Metric::Support => &self.support,
            Metric::Confidence => &self.confidence,
            Metric::Lift => &self.lift,
            Metric::Leverage => &self.leverage,
            Metric::Conviction => &self.conviction,
            Metric::Zhang => &self.zhang,
            Metric::Jaccard => &self.jaccard,
            Metric::Cosine => &self.cosine,
            Metric::Kulczynski => &self.kulczynski,
            Metric::YuleQ => &self.yule_q,
        }
    }

    /// Reconstruct the metric vector of one row.
    pub fn metrics_at(&self, row: usize) -> RuleMetrics {
        RuleMetrics {
            support: self.support[row],
            confidence: self.confidence[row],
            lift: self.lift[row],
            leverage: self.leverage[row],
            conviction: self.conviction[row],
            zhang: self.zhang[row],
            jaccard: self.jaccard[row],
            cosine: self.cosine[row],
            kulczynski: self.kulczynski[row],
            yule_q: self.yule_q[row],
        }
    }

    /// Reconstruct the rule of one row.
    pub fn rule_at(&self, row: usize) -> Rule {
        Rule::from_ids(self.antecedents[row].to_vec(), self.consequents[row].to_vec())
    }

    /// Pandas-semantics search: build the full boolean mask (every row is
    /// compared — no early exit, exactly like a dataframe filter), then
    /// return the first matching row.
    pub fn find(&self, rule: &Rule) -> Option<(usize, RuleMetrics)> {
        let a = rule.antecedent.items();
        let c = rule.consequent.items();
        // Column-at-a-time, like `(df.antecedents == a) & (df.consequents == c)`.
        let mut mask: Vec<bool> = self
            .antecedents
            .iter()
            .map(|row| row.as_ref() == a)
            .collect();
        for (m, row) in mask.iter_mut().zip(&self.consequents) {
            *m = *m && row.as_ref() == c;
        }
        mask.iter()
            .position(|&b| b)
            .map(|row| (row, self.metrics_at(row)))
    }

    /// Pandas-semantics top-N: `df.sort_values(metric, ascending=False)
    /// .head(k)` — sort_values materializes the **whole sorted frame**
    /// (every column gathered through the argsort permutation) before
    /// `head` slices it. That full-frame gather is the cost the paper's
    /// Figs. 12–13 measure.
    pub fn top_n(&self, metric: Metric, k: usize) -> Vec<(usize, f64)> {
        let col = self.column(metric);
        let mut idx: Vec<usize> = (0..col.len()).collect();
        idx.sort_by(|&a, &b| col[b].total_cmp(&col[a]));
        // sort_values: gather every column into a new frame.
        let mut sorted = RuleFrame::default();
        for &i in &idx {
            sorted.push(&self.rule_at(i), &self.metrics_at(i));
        }
        let sorted_col = sorted.column(metric);
        (0..k.min(sorted.len()))
            .map(|row| (idx[row], sorted_col[row]))
            .collect()
    }

    /// Optimized top-N (argsort of the key column only, no frame gather) —
    /// the ablation comparator showing how much of the dataframe's top-N
    /// cost is the sort_values materialization.
    pub fn top_n_lazy(&self, metric: Metric, k: usize) -> Vec<(usize, f64)> {
        let col = self.column(metric);
        let mut idx: Vec<usize> = (0..col.len()).collect();
        idx.sort_by(|&a, &b| col[b].total_cmp(&col[a]));
        idx.into_iter().take(k).map(|i| (i, col[i])).collect()
    }

    /// Row-wise traversal over raw column slices. NOTE: this is *faster*
    /// than pandas semantics (no per-row object) — it exists as the
    /// optimized-comparator ablation row. The paper-faithful traversal is
    /// [`Self::for_each_row_materialized`].
    pub fn for_each_row(&self, mut f: impl FnMut(usize, &[u32], &[u32], RuleMetrics)) {
        for row in 0..self.len() {
            f(
                row,
                &self.antecedents[row],
                &self.consequents[row],
                self.metrics_at(row),
            );
        }
    }

    /// Pandas-`iterrows` semantics: materialize the row as an owned
    /// [`Rule`] + metric vector per iteration, the way a dataframe
    /// traversal hands each rule to downstream knowledge-extraction code
    /// (and the cost center of the paper's 2-hour pandas traversal).
    pub fn for_each_row_materialized(&self, mut f: impl FnMut(usize, Rule, RuleMetrics)) {
        for row in 0..self.len() {
            f(row, self.rule_at(row), self.metrics_at(row));
        }
    }

    /// Estimated resident bytes (columns + list cells).
    pub fn memory_bytes(&self) -> usize {
        let lists: usize = self
            .antecedents
            .iter()
            .chain(&self.consequents)
            .map(|b| b.len() * 4 + 16)
            .sum();
        lists + 10 * self.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transaction::paper_example_db;
    use crate::mining::fpgrowth::fpgrowth;
    use crate::rules::rulegen::{generate_rules, RuleGenConfig};

    fn paper_frame() -> (RuleSet, RuleFrame) {
        let db = paper_example_db();
        let fi = fpgrowth(&db, 0.3);
        let rs = generate_rules(&fi, RuleGenConfig::default());
        let f = RuleFrame::from_ruleset(&rs);
        (rs, f)
    }

    #[test]
    fn find_matches_ruleset_linear_scan() {
        let (rs, f) = paper_frame();
        assert_eq!(f.len(), rs.len());
        for sr in rs.iter() {
            let (row, m) = f.find(&sr.rule).expect("rule not found");
            assert_eq!(f.rule_at(row), sr.rule);
            assert!((m.support - sr.metrics.support).abs() < 1e-15);
            assert!((m.confidence - sr.metrics.confidence).abs() < 1e-15);
        }
    }

    #[test]
    fn find_absent_returns_none() {
        let (_, f) = paper_frame();
        let bogus = Rule::from_ids(vec![9999], vec![9998]);
        assert!(f.find(&bogus).is_none());
    }

    #[test]
    fn top_n_matches_reference() {
        let (rs, f) = paper_frame();
        for metric in [Metric::Support, Metric::Confidence, Metric::Lift] {
            let want: Vec<f64> = rs
                .top_k_reference(metric, 5)
                .iter()
                .map(|sr| sr.metrics.get(metric))
                .collect();
            let got: Vec<f64> = f.top_n(metric, 5).iter().map(|&(_, v)| v).collect();
            assert_eq!(got, want, "metric {metric:?}");
            let lazy: Vec<f64> = f.top_n_lazy(metric, 5).iter().map(|&(_, v)| v).collect();
            assert_eq!(lazy, want, "lazy metric {metric:?}");
        }
    }

    #[test]
    fn traversal_covers_all_rows() {
        let (_, f) = paper_frame();
        let mut rows = 0usize;
        let mut sup_sum = 0.0;
        f.for_each_row(|_, a, c, m| {
            assert!(!a.is_empty() && !c.is_empty());
            sup_sum += m.support;
            rows += 1;
        });
        assert_eq!(rows, f.len());
        assert!(sup_sum > 0.0);
    }

    #[test]
    fn materialized_traversal_matches_slices() {
        let (_, f) = paper_frame();
        let mut sum_a = 0.0;
        let mut sum_b = 0.0;
        f.for_each_row(|_, _, _, m| sum_a += m.confidence);
        f.for_each_row_materialized(|row, rule, m| {
            assert_eq!(rule, f.rule_at(row));
            sum_b += m.confidence;
        });
        assert!((sum_a - sum_b).abs() < 1e-12);
    }

    #[test]
    fn memory_scales_with_rows() {
        let (_, f) = paper_frame();
        assert!(f.memory_bytes() > f.len() * 80);
    }
}
