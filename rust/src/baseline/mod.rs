//! Baseline ruleset representation: a columnar "dataframe" with
//! pandas-faithful operation semantics (full-scan search, full-sort top-N),
//! the comparator in every figure of the paper's evaluation.

pub mod dataframe;

pub use dataframe::RuleFrame;
