//! End-to-end driver (DESIGN.md E1/E2 headline): the full groceries-scale
//! workload through every layer, reporting the paper's headline metric —
//! per-rule search time, Trie of Rules vs the dataframe baseline (paper
//! Fig. 8: 0.000146 s vs 0.00123 s, ≈8×) with the Fig. 9 paired t-test.
//!
//! ```bash
//! cargo run --release --example market_basket
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use anyhow::Result;

use trie_of_rules::bench_support::harness::{bench_each, speedup};
use trie_of_rules::coordinator::config::PipelineConfig;
use trie_of_rules::coordinator::pipeline::{run, Source};
use trie_of_rules::data::generator::GeneratorConfig;
use trie_of_rules::rules::metrics::Metric;
use trie_of_rules::rules::ruleset::ScoredRule;
use trie_of_rules::stats::histogram::Histogram;
use trie_of_rules::stats::ttest::PairedTTest;
use trie_of_rules::trie::trie::FindOutcome;

fn main() -> Result<()> {
    // The paper's first evaluation setting: 9 834 transactions, 169 items,
    // Apriori at minsup 0.005.
    println!("building the groceries-scale workload (paper §4, first dataset)...");
    let config = PipelineConfig {
        minsup: 0.005,
        workers: 4,
        ..Default::default()
    };
    let out = run(
        Source::Generated(GeneratorConfig::groceries_like()),
        &config,
        None,
    )?;
    println!("{}", out.report.render());

    // Search workload: the trie-representable ruleset, present in both
    // structures ("every rule was searched in both data structures").
    let scored: Vec<ScoredRule> = out
        .trie
        .collect_rules()
        .into_iter()
        .map(|(rule, metrics)| ScoredRule { rule, metrics })
        .collect();
    let frame = trie_of_rules::baseline::dataframe::RuleFrame::from_scored(&scored);
    let rules: Vec<_> = scored.iter().map(|sr| sr.rule.clone()).collect();
    println!("searching all {} rules in both structures...", rules.len());

    let trie_times = bench_each(&rules, 1, |r| match out.trie.find_rule(r) {
        FindOutcome::Found(m) => m.confidence,
        _ => panic!("rule must be found"),
    });
    let frame_times = bench_each(&rules, 1, |r| {
        frame.find(r).expect("rule must be found").1.confidence
    });

    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    let sp = speedup(&trie_times, &frame_times);
    println!("\n== Fig 8 analogue: per-rule search time ==");
    println!("  trie  mean: {:.3e} s", mean(&trie_times));
    println!("  frame mean: {:.3e} s", mean(&frame_times));
    println!("  speedup: {sp:.1}x  (paper: ~8.4x)");

    println!("\n== Fig 9 analogue: paired differences (frame - trie) ==");
    let diffs: Vec<f64> = frame_times
        .iter()
        .zip(&trie_times)
        .map(|(f, t)| f - t)
        .collect();
    let hist = Histogram::of(&diffs, 20);
    print!("{}", hist.render(40));
    let t = PairedTTest::run(&frame_times, &trie_times);
    println!(
        "  paired t-test: t={:.2}, df={}, p={:.3e} -> H0 (no difference) {}",
        t.t_statistic,
        t.df,
        t.p_value,
        if t.rejects_null(0.05) {
            "REJECTED (significant)"
        } else {
            "not rejected"
        }
    );

    // Traversal comparison (the paper's large-dataset headline, scaled):
    // the trie walks every representable rule via its compressed arena
    // (for_each_split derives support+confidence in place); the frame scans
    // one row per rule.
    println!("\n== traversal: visit every rule, fold a support checksum ==");
    let t0 = std::time::Instant::now();
    let mut acc = 0.0f64;
    let mut visited = 0usize;
    out.trie.for_each_split(|_, _, sup, _| {
        acc += sup;
        visited += 1;
    });
    let trie_trav = t0.elapsed();
    let t0 = std::time::Instant::now();
    let mut acc2 = 0.0f64;
    frame.for_each_row_materialized(|_, _, m| acc2 += m.support);
    let frame_trav = t0.elapsed();
    assert!((acc - acc2).abs() < 1e-6);
    println!(
        "  trie  traverse:            {trie_trav:?} ({visited} rules)\n  frame traverse (iterrows): {frame_trav:?} ({} rows)",
        frame.len()
    );

    // Top-N sanity (Figs. 12-13 are measured properly in cargo bench).
    let k = rules.len() / 10;
    let top = out.trie.top_n(Metric::Support, k.max(1));
    println!("\n  top-10% by support: {} rules, max={:.4}", top.len(), top[0].1);

    if sp < 2.0 {
        eprintln!("WARNING: search speedup below 2x — check build profile (use --release)");
    }
    Ok(())
}
