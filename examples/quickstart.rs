//! Quickstart: mine a groceries-like dataset, build the Trie of Rules, and
//! query it — the five-minute tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use trie_of_rules::coordinator::config::PipelineConfig;
use trie_of_rules::coordinator::pipeline::{run, Source};
use trie_of_rules::coordinator::service::QueryEngine;
use trie_of_rules::data::generator::GeneratorConfig;
use trie_of_rules::rules::metrics::Metric;
use trie_of_rules::trie::compound::confidence_by_product;
use trie_of_rules::trie::trie::FindOutcome;

fn main() -> Result<()> {
    // 1. A synthetic market-basket dataset shaped like the paper's
    //    Groceries benchmark (9 834 transactions, 169 items).
    let mut gen = GeneratorConfig::groceries_like();
    gen.num_transactions = 3_000; // quick tour; benches use the full size

    // 2. Run the streaming pipeline: ingest -> shard -> mine -> rules ->
    //    Trie of Rules + dataframe baseline.
    let config = PipelineConfig {
        minsup: 0.01,
        workers: 4,
        ..Default::default()
    };
    let out = run(Source::Generated(gen), &config, None)?;
    println!("{}", out.report.render());

    // 3. Point queries: O(path-length) walks instead of full-table scans.
    //    (collect_rules() lists the rules the trie represents directly; the
    //    full ap-genrules set in `out.ruleset` also contains interleaved
    //    splits the trie reports as NotRepresentable — paper §3.3.)
    let represented = out.trie.collect_rules();
    let some_rule = represented[represented.len() / 2].0.clone();
    match out.trie.find_rule(&some_rule) {
        FindOutcome::Found(m) => println!(
            "find {}: support={:.4} confidence={:.4} lift={:.2}",
            some_rule.display(out.db.vocab()),
            m.support,
            m.confidence,
            m.lift
        ),
        other => println!("find {}: {other:?}", some_rule.display(out.db.vocab())),
    }

    // 4. Top-N without sorting the whole ruleset (bounded heap).
    println!("\ntop 5 rules by lift:");
    for (idx, lift) in out.trie.top_n(Metric::Lift, 5) {
        let path = out.trie.path_items(idx);
        let (a, c) = path.split_at(path.len() - 1);
        let names = |xs: &[u32]| {
            xs.iter()
                .map(|&i| out.db.vocab().name(i))
                .collect::<Vec<_>>()
                .join(",")
        };
        println!("  {{{}}} => {{{}}}  lift={lift:.3}", names(a), names(c));
    }

    // 5. Compound-consequent confidence by node-product (paper §3.2).
    if let Some((rule, m)) = represented.iter().find(|(r, _)| r.consequent.len() >= 2) {
        let p = confidence_by_product(&out.trie, rule).expect("representable rule");
        println!(
            "\ncompound rule {}: confidence by Eq.1-4 product = {:.4} (ratio form: {:.4})",
            rule.display(out.db.vocab()),
            p,
            m.confidence
        );
    }

    // 6. The same engine behind `tor serve`, in process.
    let engine = QueryEngine::new(out.trie, out.db.vocab().clone());
    println!("\nquery engine: {}", engine.execute("STATS"));
    Ok(())
}
