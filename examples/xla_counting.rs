//! The three-layer integration demo: Apriori support counting through the
//! AOT-compiled Pallas kernel (L1) inside the JAX graph (L2), executed from
//! rust via PJRT — versus the rust-native bitset counter.
//!
//! Requires `make artifacts` (Python runs once, at build time; this binary
//! never launches Python).
//!
//! ```bash
//! make artifacts && cargo run --release --example xla_counting
//! ```

use std::time::Instant;

use anyhow::{Context, Result};

use trie_of_rules::data::generator::GeneratorConfig;
use trie_of_rules::mining::apriori::{apriori_with, BitsetCounter};
use trie_of_rules::runtime::{default_artifacts_dir, Runtime, XlaSupportCounter};

fn main() -> Result<()> {
    let dir = default_artifacts_dir();
    let rt = Runtime::load(&dir)
        .with_context(|| format!("load artifacts from {} (run `make artifacts`)", dir.display()))?;
    println!(
        "runtime: platform={} shapes NT={} NI={} NK={}",
        rt.platform(),
        rt.manifest().shapes.nt,
        rt.manifest().shapes.ni,
        rt.manifest().shapes.nk
    );

    // Groceries-like data fits the artifact's 256-item width.
    let mut gen = GeneratorConfig::groceries_like();
    gen.num_transactions = 4_096; // one artifact chunk
    let db = gen.generate();
    println!(
        "dataset: {} transactions x {} items",
        db.num_transactions(),
        db.num_items()
    );
    let minsup = 0.01;

    // Rust-native counting.
    let t0 = Instant::now();
    let mut bitset = BitsetCounter::new(&db);
    let native = apriori_with(&db, minsup, &mut bitset);
    let native_time = t0.elapsed();

    // XLA-artifact counting (the L1 Pallas kernel through PJRT).
    let t0 = Instant::now();
    let mut xla = XlaSupportCounter::new(&rt, &db)?;
    let accel = apriori_with(&db, minsup, &mut xla);
    let xla_time = t0.elapsed();

    println!("\napriori @ minsup {minsup}:");
    println!("  bitset counter: {} itemsets in {native_time:?}", native.len());
    println!(
        "  xla counter:    {} itemsets in {xla_time:?} ({} artifact executions)",
        accel.len(),
        xla.executions
    );
    anyhow::ensure!(
        native.sets == accel.sets,
        "backends disagree — counting bug"
    );
    println!("  outputs identical: YES (itemsets and supports match exactly)");

    println!(
        "\nnote: the CPU PJRT path runs the Pallas kernel in interpret-mode\n\
         lowering; it validates the architecture and numerics, not TPU speed\n\
         (see DESIGN.md §Hardware-Adaptation for the MXU analysis)."
    );
    Ok(())
}
