//! Retail-scale analysis — the paper's second experiment (§4, the UCI
//! Online Retail analogue): a sparser, much larger ruleset, where the trie
//! pays more at construction time but wins traversal by a large factor
//! (paper: build 25 min vs 2 min; traverse 25 min vs >2 h).
//!
//! Runs a scaled-down retail-like workload (ratios, not minutes, are the
//! reproduction target — DESIGN.md §5.2), then exercises knowledge-
//! extraction queries: consequent-indexed scans through the header table
//! and compound-consequent confidence derivation.
//!
//! ```bash
//! cargo run --release --example retail_analysis
//! ```

use std::time::Instant;

use anyhow::Result;

use trie_of_rules::baseline::dataframe::RuleFrame;
use trie_of_rules::coordinator::config::PipelineConfig;
use trie_of_rules::coordinator::pipeline::{run, Source};
use trie_of_rules::data::generator::GeneratorConfig;
use trie_of_rules::rules::ruleset::ScoredRule;
use trie_of_rules::trie::compound::verify_eq4;

fn main() -> Result<()> {
    // Scaled retail-like source: full 3 600-item vocabulary, reduced
    // transaction count so the example finishes in seconds.
    let mut gen = GeneratorConfig::retail_like();
    gen.num_transactions = 6_000;
    println!(
        "retail-like source: {} transactions x {} items",
        gen.num_transactions, gen.num_items
    );

    let config = PipelineConfig {
        // Calibrated to the paper's retail ruleset scale (DESIGN.md §5.2);
        // lower thresholds explode combinatorially on the dense generator.
        minsup: 0.015,
        workers: 4,
        chunk_size: 256,
        ..Default::default()
    };

    // Construction-time comparison (paper Fig. 11 / §4): time the builds
    // separately.
    let out = run(Source::Generated(gen), &config, None)?;
    println!("{}", out.report.render());
    let build_trie = out
        .report
        .stages
        .iter()
        .find(|s| s.name == "build-trie")
        .map(|s| s.duration)
        .unwrap_or_default();
    let build_frame = out
        .report
        .stages
        .iter()
        .find(|s| s.name == "build-frame")
        .map(|s| s.duration)
        .unwrap_or_default();
    println!(
        "construction: trie {build_trie:?} vs frame {build_frame:?} (paper: trie costs more up front)"
    );

    // Traversal comparison over the shared representable ruleset.
    let scored: Vec<ScoredRule> = out
        .trie
        .collect_rules()
        .into_iter()
        .map(|(rule, metrics)| ScoredRule { rule, metrics })
        .collect();
    let frame = RuleFrame::from_scored(&scored);
    println!("ruleset size: {} rules", scored.len());

    let t0 = Instant::now();
    let mut high_conf = 0usize;
    out.trie.for_each_split(|_, _, _, conf| {
        if conf > 0.8 {
            high_conf += 1;
        }
    });
    let trie_trav = t0.elapsed();
    let t0 = Instant::now();
    let mut high_conf2 = 0usize;
    frame.for_each_row_materialized(|_, _, m| {
        if m.confidence > 0.8 {
            high_conf2 += 1;
        }
    });
    let frame_trav = t0.elapsed();
    assert_eq!(high_conf, high_conf2);
    println!(
        "traversal (count conf>0.8 = {high_conf}): trie {trie_trav:?} vs frame {frame_trav:?} ({:.1}x)",
        frame_trav.as_secs_f64() / trie_trav.as_secs_f64().max(1e-12)
    );

    // Knowledge extraction: which item has the richest driver set? (Note:
    // the globally most-frequent item ranks first in every path, so it is
    // never a stored consequent — pick the item with the most node-rules
    // via the header table.)
    let top_item = out
        .order
        .frequent_items()
        .iter()
        .copied()
        .max_by_key(|&i| out.trie.rules_with_consequent(i).len())
        .expect("frequent items");
    let drivers = out.trie.rules_with_consequent(top_item);
    println!(
        "\nrules with consequent {{{}}} (header-table scan): {}",
        out.db.vocab().name(top_item),
        drivers.len()
    );
    for (idx, m) in drivers.iter().take(5) {
        let path = out.trie.path_items(*idx);
        let a: Vec<&str> = path[..path.len() - 1]
            .iter()
            .map(|&i| out.db.vocab().name(i))
            .collect();
        println!("  {{{}}} conf={:.3} lift={:.2}", a.join(","), m.confidence, m.lift);
    }

    // Eq. 1-4 spot-check on every compound rule in the first 500.
    let mut checked = 0;
    for sr in scored.iter().filter(|sr| sr.rule.consequent.len() >= 2).take(500) {
        assert!(
            verify_eq4(&out.trie, &sr.rule, 1e-9),
            "Eq.4 violated for {}",
            sr.rule.display(out.db.vocab())
        );
        checked += 1;
    }
    println!("\nEq. 1-4 verified on {checked} compound-consequent rules");
    Ok(())
}
